//! # masft — Morlet wavelet transform via attenuated sliding Fourier transform
//!
//! A three-layer reproduction of Yamashita & Wakahara (2021), *"Morlet wavelet
//! transform using attenuated sliding Fourier transform and kernel integral
//! for graphic processing unit"*:
//!
//! * **Layer 1** (build-time Python/Pallas): the paper's log-depth sliding-sum
//!   kernel, fused with SFT modulation — see `python/compile/kernels/`.
//! * **Layer 2** (build-time JAX): the generic weighted-SFT-bank transform
//!   graph, AOT-lowered to HLO text in `artifacts/`.
//! * **Layer 3** (this crate): every algorithm of the paper in pure Rust
//!   ([`sft`], [`gaussian`], [`morlet`], [`slidingsum`]), the MMSE fitting
//!   machinery ([`coeffs`]), the GPU cost model that regenerates the paper's
//!   timing figures ([`gpu_model`]), the f32-drift study that motivates ASFT
//!   ([`precision`]), the PJRT runtime that executes the AOT artifacts
//!   ([`runtime`]), and a batching request coordinator ([`coordinator`]).
//!
//! The crate is usable entirely without artifacts (pure-Rust paths); the
//! [`runtime`]/[`coordinator`] layers additionally serve the AOT kernels.
//!
//! ## Quick start
//!
//! ```no_run
//! use masft::gaussian::GaussianSmoother;
//! use masft::morlet::{MorletTransform, Method};
//!
//! let x: Vec<f64> = (0..4096).map(|i| (i as f64 * 0.05).sin()).collect();
//! // Gaussian smoothing, SFT path, P = 6 (the paper's GDP6).
//! let smoother = GaussianSmoother::new(64.0, 6).unwrap();
//! let y = smoother.smooth_sft(&x);
//! // Morlet transform, direct method (the paper's MDP6).
//! let mt = MorletTransform::new(60.0, 6.0, Method::DirectSft { p_d: 6 }).unwrap();
//! let z = mt.transform(&x);
//! assert_eq!(y.len(), x.len());
//! assert_eq!(z.len(), x.len());
//! ```

pub mod bench_harness;
pub mod coeffs;
pub mod coordinator;
pub mod dsp;
pub mod gaussian;
pub mod gpu_model;
pub mod image;
pub mod linalg;
pub mod morlet;
pub mod precision;
pub mod runtime;
pub mod sft;
pub mod slidingsum;
pub mod streaming;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
