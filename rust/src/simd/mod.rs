//! Portable SIMD layer for the elementwise hot paths.
//!
//! The paper's argument (§2, §4) is that the kernel-integral SFT turns
//! Gaussian/Morlet smoothing into cheap *pointwise* work plus log-depth
//! sliding sums; on the CPU reproduction those pointwise banks are the
//! dominant per-lane cost. This module provides the vectorized form of that
//! elementwise layer:
//!
//! * [`F64x4`] / [`C64x2`] and their f32 twins [`F32x8`] / [`C32x4`] —
//!   fixed-width lane bundles over plain arrays. Stable Rust only (no
//!   `std::simd`, no intrinsics, no dependencies — mirroring how
//!   [`crate::exec`] stayed dependency-free): the explicit 4/8-wide
//!   structure gives LLVM straight-line, branch-free blocks it reliably
//!   autovectorizes, without committing the crate to a nightly toolchain or
//!   a target feature set. The f32 bundles carry twice the lanes at the
//!   same register width — the [`crate::plan::Precision::F32`] tier's
//!   throughput lever. [`SimdFloat`] maps each precision to its bundle, so
//!   the width-generic kernels below serve both tiers from one body.
//! * Vectorized kernels for every elementwise hot path: the fused weighted
//!   SFT bank ([`weighted_bank_into`], the engine of eqs. 13-15 and 54), the
//!   ASFT attenuation/rotation bank ([`asft_components_r1_bank`], eq. 37
//!   across all orders in one signal pass), the §4 sliding sums
//!   ([`sliding_sum_doubling`], [`sliding_sum_blocked`]), the Morlet carrier
//!   application ([`scale_complex_into`], the §3 phase/scale weight), and
//!   the axpy-style weighted accumulations ([`axpy`], [`axpy_complex`])
//!   used by the Gaussian reconstruction and the separable image passes.
//!
//! # Bit-identity contract
//!
//! Every kernel here performs, per lane, **exactly the arithmetic of its
//! scalar reference in exactly the same order** — lanes are independent
//! (bank orders, output samples), so grouping four of them into an [`F64x4`]
//! reorders nothing. Cross-lane accumulations (the weighted-bank output sum)
//! are reduced sequentially in ascending lane order, matching the scalar
//! loop. The result: `Backend::Simd` output is **bit-identical** to the
//! scalar path on all purely elementwise surfaces, and the sliding sums
//! reproduce the scalar fixed-association tree exactly (each output element
//! is one shifted add per step, no reassociation). `rust/tests/simd_parity.rs`
//! asserts exact equality on every routed surface; keep the scalar and SIMD
//! bodies in lockstep when editing either.
//!
//! The scalar implementations remain the reference path
//! ([`crate::plan::Backend::PureRust`], the default); select this layer per
//! spec with [`crate::plan::Backend::Simd`]. It composes with
//! [`crate::exec::Parallelism`]: each exec worker runs vectorized lanes.

use crate::dsp::{Complex, Float};
use crate::sft::kernel_integral::{Rotor, WeightedTerm};
use crate::sft::Components;
use crate::slidingsum::{bit, BlockedStats, StepStats};

/// Lane width of [`F64x4`] (and of the f64-only kernels below).
pub const LANES: usize = 4;

/// The elementwise operations a precision's lane bundle provides — the
/// generic face of [`F64x4`] and [`F32x8`] used by the width-generic
/// kernels ([`weighted_bank_into`], the sliding sums, and the streaming
/// [`crate::streaming`] bank).
///
/// Implementations must act elementwise with ordinary IEEE-754 semantics
/// (no FMA contraction, no reassociation), so each lane computes exactly
/// what the corresponding scalar expression computes — the bit-identity
/// contract of this module stated once, for both precisions.
pub trait LaneVec<T>:
    Copy
    + std::fmt::Debug
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
{
    /// Number of lanes in the bundle.
    const WIDTH: usize;
    /// All lanes set to `v`.
    fn splat(v: T) -> Self;
    /// Load the first `WIDTH` elements of `s` (panics when too short).
    fn load(s: &[T]) -> Self;
    /// Store the lanes into the first `WIDTH` elements of `s`.
    fn store(self, s: &mut [T]);
    /// Lane `i` as a scalar.
    fn lane(self, i: usize) -> T;
}

/// Floats with a portable lane bundle: `f64` → [`F64x4`], `f32` → [`F32x8`].
/// This is the trait the [`crate::plan::Precision`] tiers instantiate the
/// shared kernels at; the f32 bundle doubles the lane count at the same
/// register width.
pub trait SimdFloat: Float {
    /// The lane bundle of this precision.
    type Vec: LaneVec<Self>;
}

impl SimdFloat for f64 {
    type Vec = F64x4;
}

impl SimdFloat for f32 {
    type Vec = F32x8;
}

/// Four `f64` lanes over a plain array — the portable SIMD word.
///
/// All operators act elementwise with ordinary IEEE-754 `f64` semantics
/// (no FMA contraction, no reassociation), so each lane computes exactly
/// what the corresponding scalar expression computes.
#[derive(Copy, Clone, Debug, Default, PartialEq)]
pub struct F64x4(pub [f64; 4]);

impl F64x4 {
    /// All four lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: f64) -> Self {
        Self([v; 4])
    }

    /// Load the first four elements of `s` (panics if `s.len() < 4`).
    #[inline(always)]
    pub fn load(s: &[f64]) -> Self {
        Self([s[0], s[1], s[2], s[3]])
    }

    /// Store the four lanes into the first four elements of `s`.
    #[inline(always)]
    pub fn store(self, s: &mut [f64]) {
        s[..4].copy_from_slice(&self.0);
    }

    /// The lanes as a plain array.
    #[inline(always)]
    pub fn to_array(self) -> [f64; 4] {
        self.0
    }
}

impl std::ops::Add for F64x4 {
    type Output = Self;
    #[inline(always)]
    fn add(self, r: Self) -> Self {
        Self([
            self.0[0] + r.0[0],
            self.0[1] + r.0[1],
            self.0[2] + r.0[2],
            self.0[3] + r.0[3],
        ])
    }
}

impl std::ops::Sub for F64x4 {
    type Output = Self;
    #[inline(always)]
    fn sub(self, r: Self) -> Self {
        Self([
            self.0[0] - r.0[0],
            self.0[1] - r.0[1],
            self.0[2] - r.0[2],
            self.0[3] - r.0[3],
        ])
    }
}

impl std::ops::Mul for F64x4 {
    type Output = Self;
    #[inline(always)]
    fn mul(self, r: Self) -> Self {
        Self([
            self.0[0] * r.0[0],
            self.0[1] * r.0[1],
            self.0[2] * r.0[2],
            self.0[3] * r.0[3],
        ])
    }
}

impl std::ops::Neg for F64x4 {
    type Output = Self;
    #[inline(always)]
    fn neg(self) -> Self {
        Self([-self.0[0], -self.0[1], -self.0[2], -self.0[3]])
    }
}

/// Two complex `f64` lanes in planar (re/im-split) form.
///
/// [`C64x2::mul`] and [`C64x2::scale`] mirror [`Complex`]'s expressions
/// lane-for-lane, so complex SIMD arithmetic is bit-identical to the scalar
/// complex type.
#[derive(Copy, Clone, Debug, Default, PartialEq)]
pub struct C64x2 {
    /// Real parts of the two lanes.
    pub re: [f64; 2],
    /// Imaginary parts of the two lanes.
    pub im: [f64; 2],
}

impl C64x2 {
    /// Both lanes set to `w`.
    #[inline(always)]
    pub fn splat(w: Complex<f64>) -> Self {
        Self {
            re: [w.re; 2],
            im: [w.im; 2],
        }
    }

    /// Lanes from two scalar complex values.
    #[inline(always)]
    pub fn from_complex(a: Complex<f64>, b: Complex<f64>) -> Self {
        Self {
            re: [a.re, b.re],
            im: [a.im, b.im],
        }
    }

    /// Lane `i` as a scalar complex value.
    #[inline(always)]
    pub fn lane(self, i: usize) -> Complex<f64> {
        Complex::new(self.re[i], self.im[i])
    }

    /// Elementwise complex product, the exact expression of
    /// `Complex::mul`: `re = a.re·b.re − a.im·b.im`,
    /// `im = a.re·b.im + a.im·b.re`.
    #[inline(always)]
    pub fn mul(self, r: Self) -> Self {
        Self {
            re: [
                self.re[0] * r.re[0] - self.im[0] * r.im[0],
                self.re[1] * r.re[1] - self.im[1] * r.im[1],
            ],
            im: [
                self.re[0] * r.im[0] + self.im[0] * r.re[0],
                self.re[1] * r.im[1] + self.im[1] * r.re[1],
            ],
        }
    }

    /// Elementwise real scaling (the expression of `Complex::scale`).
    #[inline(always)]
    pub fn scale(self, s: f64) -> Self {
        Self {
            re: [self.re[0] * s, self.re[1] * s],
            im: [self.im[0] * s, self.im[1] * s],
        }
    }

    /// Elementwise complex addition.
    #[inline(always)]
    pub fn add(self, r: Self) -> Self {
        Self {
            re: [self.re[0] + r.re[0], self.re[1] + r.re[1]],
            im: [self.im[0] + r.im[0], self.im[1] + r.im[1]],
        }
    }
}

impl LaneVec<f64> for F64x4 {
    const WIDTH: usize = 4;
    #[inline(always)]
    fn splat(v: f64) -> Self {
        F64x4::splat(v)
    }
    #[inline(always)]
    fn load(s: &[f64]) -> Self {
        F64x4::load(s)
    }
    #[inline(always)]
    fn store(self, s: &mut [f64]) {
        F64x4::store(self, s)
    }
    #[inline(always)]
    fn lane(self, i: usize) -> f64 {
        self.0[i]
    }
}

/// Eight `f32` lanes over a plain array — the f32 tier's portable SIMD
/// word. Same register width as [`F64x4`], twice the lanes.
///
/// All operators act elementwise with ordinary IEEE-754 `f32` semantics
/// (no FMA contraction, no reassociation), so each lane computes exactly
/// what the corresponding scalar-f32 expression computes — the same parity
/// discipline as [`F64x4`].
#[derive(Copy, Clone, Debug, Default, PartialEq)]
pub struct F32x8(pub [f32; 8]);

impl F32x8 {
    /// All eight lanes set to `v`.
    #[inline(always)]
    pub fn splat(v: f32) -> Self {
        Self([v; 8])
    }

    /// Load the first eight elements of `s` (panics if `s.len() < 8`).
    #[inline(always)]
    pub fn load(s: &[f32]) -> Self {
        Self([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]])
    }

    /// Store the eight lanes into the first eight elements of `s`.
    #[inline(always)]
    pub fn store(self, s: &mut [f32]) {
        s[..8].copy_from_slice(&self.0);
    }

    /// The lanes as a plain array.
    #[inline(always)]
    pub fn to_array(self) -> [f32; 8] {
        self.0
    }
}

impl std::ops::Add for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn add(self, r: Self) -> Self {
        let (a, b) = (self.0, r.0);
        Self([
            a[0] + b[0],
            a[1] + b[1],
            a[2] + b[2],
            a[3] + b[3],
            a[4] + b[4],
            a[5] + b[5],
            a[6] + b[6],
            a[7] + b[7],
        ])
    }
}

impl std::ops::Sub for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn sub(self, r: Self) -> Self {
        let (a, b) = (self.0, r.0);
        Self([
            a[0] - b[0],
            a[1] - b[1],
            a[2] - b[2],
            a[3] - b[3],
            a[4] - b[4],
            a[5] - b[5],
            a[6] - b[6],
            a[7] - b[7],
        ])
    }
}

impl std::ops::Mul for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn mul(self, r: Self) -> Self {
        let (a, b) = (self.0, r.0);
        Self([
            a[0] * b[0],
            a[1] * b[1],
            a[2] * b[2],
            a[3] * b[3],
            a[4] * b[4],
            a[5] * b[5],
            a[6] * b[6],
            a[7] * b[7],
        ])
    }
}

impl std::ops::Neg for F32x8 {
    type Output = Self;
    #[inline(always)]
    fn neg(self) -> Self {
        let a = self.0;
        Self([-a[0], -a[1], -a[2], -a[3], -a[4], -a[5], -a[6], -a[7]])
    }
}

impl LaneVec<f32> for F32x8 {
    const WIDTH: usize = 8;
    #[inline(always)]
    fn splat(v: f32) -> Self {
        F32x8::splat(v)
    }
    #[inline(always)]
    fn load(s: &[f32]) -> Self {
        F32x8::load(s)
    }
    #[inline(always)]
    fn store(self, s: &mut [f32]) {
        F32x8::store(self, s)
    }
    #[inline(always)]
    fn lane(self, i: usize) -> f32 {
        self.0[i]
    }
}

/// Four complex `f32` lanes in planar (re/im-split) form — the f32 twin of
/// [`C64x2`], used by the f32-tier Morlet carrier epilogue.
///
/// [`C32x4::mul`] and [`C32x4::scale`] mirror [`Complex`]'s expressions
/// lane-for-lane, so complex f32 SIMD arithmetic is bit-identical to the
/// scalar `Complex<f32>` type.
#[derive(Copy, Clone, Debug, Default, PartialEq)]
pub struct C32x4 {
    /// Real parts of the four lanes.
    pub re: [f32; 4],
    /// Imaginary parts of the four lanes.
    pub im: [f32; 4],
}

impl C32x4 {
    /// All four lanes set to `w`.
    #[inline(always)]
    pub fn splat(w: Complex<f32>) -> Self {
        Self {
            re: [w.re; 4],
            im: [w.im; 4],
        }
    }

    /// Lane `i` as a scalar complex value.
    #[inline(always)]
    pub fn lane(self, i: usize) -> Complex<f32> {
        Complex::new(self.re[i], self.im[i])
    }

    /// Elementwise complex product — the exact expression of
    /// `Complex::mul` per lane.
    #[inline(always)]
    pub fn mul(self, r: Self) -> Self {
        let mut re = [0.0f32; 4];
        let mut im = [0.0f32; 4];
        for t in 0..4 {
            re[t] = self.re[t] * r.re[t] - self.im[t] * r.im[t];
            im[t] = self.re[t] * r.im[t] + self.im[t] * r.re[t];
        }
        Self { re, im }
    }

    /// Elementwise real scaling (the expression of `Complex::scale`).
    #[inline(always)]
    pub fn scale(self, s: f32) -> Self {
        let mut re = [0.0f32; 4];
        let mut im = [0.0f32; 4];
        for t in 0..4 {
            re[t] = self.re[t] * s;
            im[t] = self.im[t] * s;
        }
        Self { re, im }
    }

    /// Elementwise complex addition.
    #[inline(always)]
    pub fn add(self, r: Self) -> Self {
        let mut re = [0.0f32; 4];
        let mut im = [0.0f32; 4];
        for t in 0..4 {
            re[t] = self.re[t] + r.re[t];
            im[t] = self.im[t] + r.im[t];
        }
        Self { re, im }
    }
}

// ---------------------------------------------------------------------------
// Fused weighted SFT bank (the kernel-integral hot path)
// ---------------------------------------------------------------------------

/// Allocating convenience wrapper around [`weighted_bank_into`] — the SIMD
/// twin of [`crate::sft::kernel_integral::weighted_bank`].
pub fn weighted_bank<T: SimdFloat>(
    x: &[T],
    k: usize,
    beta: f64,
    terms: &[WeightedTerm],
) -> (Vec<T>, Vec<T>) {
    let n = x.len();
    let mut re = vec![T::ZERO; n];
    let mut im = vec![T::ZERO; n];
    let mut lane_buf = Vec::new();
    weighted_bank_into(x, k, beta, terms, &mut re, &mut im, &mut lane_buf);
    (re, im)
}

/// Vectorized fused weighted SFT bank — the SIMD twin of
/// [`crate::sft::kernel_integral::weighted_bank_into`], and the engine
/// behind [`crate::plan::Backend::Simd`] on the Gaussian/Morlet plans.
///
/// Same contract as the scalar form: `re`/`im` are `x.len()` long, cleared
/// first; `lane_buf` holds the per-lane filter state (grows to
/// `10 × terms.len()` once, then reused — the zero-allocation property
/// survives). Lane state updates run [`LaneVec::WIDTH`] bank orders at a
/// time in [`F64x4`]/[`F32x8`] blocks (identical per-lane expressions), and
/// the per-sample output reduction adds lane products in ascending order
/// exactly like the scalar loop — at either precision, output is
/// **bit-identical** to the scalar path of that precision.
pub fn weighted_bank_into<T: SimdFloat>(
    x: &[T],
    k: usize,
    beta: f64,
    terms: &[WeightedTerm],
    re: &mut [T],
    im: &mut [T],
    lane_buf: &mut Vec<T>,
) {
    let n = x.len();
    assert_eq!(re.len(), n, "re output length must equal the signal length");
    assert_eq!(im.len(), n, "im output length must equal the signal length");
    for v in re.iter_mut() {
        *v = T::ZERO;
    }
    for v in im.iter_mut() {
        *v = T::ZERO;
    }
    if n == 0 || terms.is_empty() {
        return;
    }
    let ki = k as isize;
    let ni = n as isize;
    let lanes = terms.len();

    // Identical state layout and warm-up to the scalar reference (see
    // `kernel_integral::weighted_bank_into` §Perf iteration 6 notes).
    // Constants are derived in f64 and narrowed once, exactly as the
    // scalar generic body does.
    lane_buf.clear();
    lane_buf.resize(10 * lanes, T::ZERO);
    let (w_re, rest) = lane_buf.split_at_mut(lanes);
    let (w_im, rest) = rest.split_at_mut(lanes);
    let (pole_re, rest) = rest.split_at_mut(lanes);
    let (pole_im, rest) = rest.split_at_mut(lanes);
    let (cin_re, rest) = rest.split_at_mut(lanes);
    let (cin_im, rest) = rest.split_at_mut(lanes);
    let (cout_re, rest) = rest.split_at_mut(lanes);
    let (cout_im, rest) = rest.split_at_mut(lanes);
    let (mw, lw) = rest.split_at_mut(lanes);
    for (j, t) in terms.iter().enumerate() {
        let om = beta * t.p;
        pole_re[j] = T::from_f64(om.cos());
        pole_im[j] = T::from_f64(-om.sin()); // e^{-iω}
        let thk = om * k as f64;
        cin_re[j] = T::from_f64(thk.cos());
        cin_im[j] = T::from_f64(thk.sin()); // e^{iωK}
        let tho = -om * (k as f64 + 1.0);
        cout_re[j] = T::from_f64(tho.cos());
        cout_im[j] = T::from_f64(tho.sin()); // e^{-iω(K+1)}
        mw[j] = T::from_f64(t.m);
        lw[j] = T::from_f64(t.l);
        // warm-up: w̃[−1] = e^{iω}·Σ_{jj=0}^{K−1} x[jj]·e^{iω·jj}
        let mut rot = Rotor::<T>::new(om, om);
        for &xv in x.iter().take(k.min(n)) {
            let w = rot.next_val();
            w_re[j] += w.re * xv;
            w_im[j] += w.im * xv;
        }
    }

    let width = T::Vec::WIDTH;
    let blocks = lanes - lanes % width;
    for i in 0..ni {
        let j_in = i + ki;
        let x_in = if j_in < ni { x[j_in as usize] } else { T::ZERO };
        let j_out = i - ki - 1;
        let x_out = if j_out >= 0 { x[j_out as usize] } else { T::ZERO };
        let xin_v = T::Vec::splat(x_in);
        let xout_v = T::Vec::splat(x_out);
        let mut acc_re = T::ZERO;
        let mut acc_im = T::ZERO;
        let mut j = 0;
        while j < blocks {
            let pr = T::Vec::load(&pole_re[j..]);
            let pi = T::Vec::load(&pole_im[j..]);
            let wr0 = T::Vec::load(&w_re[j..]);
            let wi0 = T::Vec::load(&w_im[j..]);
            // same expression tree as the scalar lane body
            let wr = pr * wr0 - pi * wi0 + xin_v * T::Vec::load(&cin_re[j..])
                - xout_v * T::Vec::load(&cout_re[j..]);
            let wi = pr * wi0 + pi * wr0 + xin_v * T::Vec::load(&cin_im[j..])
                - xout_v * T::Vec::load(&cout_im[j..]);
            wr.store(&mut w_re[j..]);
            wi.store(&mut w_im[j..]);
            let prod_re = T::Vec::load(&mw[j..]) * wr;
            let prod_im = T::Vec::load(&lw[j..]) * wi;
            // sequential reduction in ascending lane order = scalar order
            for t in 0..width {
                acc_re += prod_re.lane(t);
                acc_im -= prod_im.lane(t);
            }
            j += width;
        }
        while j < lanes {
            let (pr, pi) = (pole_re[j], pole_im[j]);
            let (wr0, wi0) = (w_re[j], w_im[j]);
            let wr = pr * wr0 - pi * wi0 + x_in * cin_re[j] - x_out * cout_re[j];
            let wi = pr * wi0 + pi * wr0 + x_in * cin_im[j] - x_out * cout_im[j];
            w_re[j] = wr;
            w_im[j] = wi;
            acc_re += mw[j] * wr;
            acc_im -= lw[j] * wi;
            j += 1;
        }
        re[i as usize] = acc_re;
        im[i as usize] = acc_im;
    }
}

// ---------------------------------------------------------------------------
// ASFT attenuation/rotation bank (eq. 37 across orders)
// ---------------------------------------------------------------------------

/// All-orders ASFT component bank via the attenuated first-order filter —
/// the SIMD twin of calling [`crate::sft::asft::components_r1`] once per
/// order in `ps`, in **one signal pass**.
///
/// The attenuation/rotation state update `ṽ = q·ṽ + d` (eq. 37) is
/// independent across orders, so four orders advance per [`F64x4`] block
/// with the exact per-lane expressions of the scalar `Complex` arithmetic
/// (including the `+ 0.0` imaginary term of the real-valued drive) —
/// per-order output is bit-identical to the scalar function. Orders beyond
/// the last full block fall back to the scalar reference directly.
pub fn asft_components_r1_bank(
    x: &[f64],
    k: usize,
    ps: &[usize],
    alpha: f64,
) -> Vec<Components<f64>> {
    let n = x.len();
    let beta = std::f64::consts::PI / k as f64;
    let decay = (-alpha).exp();
    let q2k = (-alpha * 2.0 * k as f64).exp();
    let scale = (alpha * k as f64).exp();
    let get = |j: isize| -> f64 {
        if j >= 0 && (j as usize) < n {
            x[j as usize]
        } else {
            0.0
        }
    };

    let blocks = ps.len() - ps.len() % LANES;
    // block lanes fill their buffers sample by sample; remainder orders are
    // pushed whole from the scalar reference below, so only the block lanes
    // pre-allocate
    let mut out: Vec<Components<f64>> = Vec::with_capacity(ps.len());
    for _ in 0..blocks {
        out.push(Components {
            c: Vec::with_capacity(n),
            s: Vec::with_capacity(n),
        });
    }

    let ki = k as isize;
    let l2 = 2 * k as isize;
    let mut b = 0;
    while b < blocks {
        // pole q = e^{-α-iβp} per lane, sign·scale per lane
        let mut pr = [0.0; 4];
        let mut pi = [0.0; 4];
        let mut ss = [0.0; 4];
        for t in 0..LANES {
            let p = ps[b + t];
            // exact expressions of the scalar path:
            // Complex::cis(-beta * p as f64).scale(decay)
            let theta = -beta * p as f64;
            pr[t] = theta.cos() * decay;
            pi[t] = theta.sin() * decay;
            let sign = if p % 2 == 0 { 1.0 } else { -1.0 };
            ss[t] = sign * scale;
        }
        let pr = F64x4(pr);
        let pi = F64x4(pi);
        let mut vr = F64x4::splat(0.0);
        let mut vi = F64x4::splat(0.0);
        let zero = F64x4::splat(0.0);
        for m in 0..(n as isize + ki) {
            let d = get(m) - q2k * get(m - l2);
            // v = pole*v + (d, 0): re = (pr·vr − pi·vi) + d,
            //                      im = (pr·vi + pi·vr) + 0.0
            let vr_new = pr * vr - pi * vi + F64x4::splat(d);
            let vi_new = pr * vi + pi * vr + zero;
            vr = vr_new;
            vi = vi_new;
            if m >= ki {
                let i = m - ki;
                let q2kx = q2k * get(i - ki);
                // out = (v + (q2kx, 0)).scale(sign·scale); push (re, −im)
                let or4 = (vr + F64x4::splat(q2kx)) * F64x4(ss);
                let oi4 = (vi + zero) * F64x4(ss);
                for t in 0..LANES {
                    out[b + t].c.push(or4.0[t]);
                    out[b + t].s.push(-oi4.0[t]);
                }
            }
        }
        b += LANES;
    }
    // remainder orders: the scalar reference itself
    for &p in &ps[blocks..] {
        out.push(crate::sft::asft::components_r1(x, k, p, alpha));
    }
    out
}

// ---------------------------------------------------------------------------
// Sliding sums (§4, Algorithms 1-3)
// ---------------------------------------------------------------------------

/// Vectorized Algorithm 1 (log-depth doubling sliding sum) — the SIMD twin
/// of [`crate::slidingsum::sliding_sum_doubling`], width-generic over the
/// precision tiers.
///
/// Each whole-row step `g[i] += g[i+2^r]` / `h[i] = g[i] + h[i+2^r]` is one
/// shifted elementwise add: every output element is a single two-operand
/// addition, so blocking the row into [`F64x4`]/[`F32x8`] words changes
/// neither the association tree nor the values — output and [`StepStats`]
/// are identical to the scalar form of the same precision (reads always see
/// pre-step values: a lane's read index `i + 2^r` always exceeds every
/// index written before it in the pass, in both the scalar and the blocked
/// order).
pub fn sliding_sum_doubling<T: SimdFloat>(f: &[T], l: usize) -> (Vec<T>, StepStats) {
    let n = f.len();
    let mut stats = StepStats::default();
    if l == 0 || n == 0 {
        return (vec![T::ZERO; n], stats);
    }
    let mut r_max = 0;
    while (1usize << r_max) <= l {
        r_max += 1;
    }
    let mut g = f.to_vec();
    let mut h = vec![T::ZERO; n];
    for r in 0..r_max {
        let step = 1usize << r;
        if bit(l, r) {
            shifted_add_rows(&g, &mut h, step);
            stats.depth += 1;
            stats.additions += n as u64;
            stats.global_accesses += 3 * n as u64;
        }
        doubling_step(&mut g, step);
        stats.depth += 1;
        stats.additions += n as u64;
        stats.global_accesses += 3 * n as u64;
    }
    (h, stats)
}

/// One h-merge row: `h[i] = g[i] + h[i+step]` (zero past the end).
fn shifted_add_rows<T: SimdFloat>(g: &[T], h: &mut [T], step: usize) {
    let n = g.len();
    let width = T::Vec::WIDTH;
    let lim = n.saturating_sub(step);
    let mut i = 0;
    while i + width <= lim {
        let a = T::Vec::load(&g[i..]);
        let b = T::Vec::load(&h[i + step..]);
        (a + b).store(&mut h[i..]);
        i += width;
    }
    while i < n {
        let hn = if i + step < n { h[i + step] } else { T::ZERO };
        h[i] = g[i] + hn;
        i += 1;
    }
}

/// One g-doubling row: `g[i] += g[i+step]` (zero past the end).
fn doubling_step<T: SimdFloat>(g: &mut [T], step: usize) {
    let n = g.len();
    let width = T::Vec::WIDTH;
    let lim = n.saturating_sub(step);
    let mut i = 0;
    while i + width <= lim {
        let a = T::Vec::load(&g[i..]);
        let b = T::Vec::load(&g[i + step..]);
        (a + b).store(&mut g[i..]);
        i += width;
    }
    while i < n {
        let gn = if i + step < n { g[i + step] } else { T::ZERO };
        g[i] += gn;
        i += 1;
    }
}

/// Vectorized Algorithms 2-3 (shared-memory radix-8 blocked sliding sum) —
/// the SIMD twin of [`crate::slidingsum::sliding_sum_blocked`],
/// width-generic over the precision tiers. The three gated doubling steps
/// inside each 16-lane tile run in [`F64x4`]/[`F32x8`] blocks (loads
/// complete before the block's stores, preserving the scalar pre-step-read
/// order); output and [`BlockedStats`] are identical to the scalar form of
/// the same precision.
pub fn sliding_sum_blocked<T: SimdFloat>(f: &[T], l: usize) -> (Vec<T>, BlockedStats) {
    let n = f.len();
    let mut stats = BlockedStats::default();
    if l == 0 || n == 0 {
        return (vec![T::ZERO; n], stats);
    }
    let width = T::Vec::WIDTH;
    let mut g = f.to_vec();
    let mut h = vec![T::ZERO; n];
    let mut rem = l;
    let mut stride = 1usize;

    while rem > 0 {
        let bits = [bit(rem, 0), bit(rem, 1), bit(rem, 2)];
        stats.stages += 1;
        stats.depth += 3 + 2;

        let tile_span = 8 * stride;
        let mut g_next = g.clone();
        let mut h_next = h.clone();
        let mut q = 0usize;
        while q * tile_span < n {
            for b in 0..stride.min(n - q * tile_span) {
                let o = q * tile_span + b;
                let mut s = [T::ZERO; 16];
                let mut t = [T::ZERO; 16];
                for (j, (sj, tj)) in s.iter_mut().zip(t.iter_mut()).enumerate() {
                    let idx = o + j * stride;
                    if idx < n {
                        *sj = g[idx];
                        *tj = h[idx];
                    }
                }
                stats.global_accesses += 32;

                for (r, &b_set) in bits.iter().enumerate() {
                    let step = 1usize << r;
                    let upper = 16 - step;
                    let mut j = 0;
                    while j + width <= upper {
                        let sj = T::Vec::load(&s[j..]);
                        let sn = T::Vec::load(&s[j + step..]);
                        if b_set {
                            let tn = T::Vec::load(&t[j + step..]);
                            (sj + tn).store(&mut t[j..]);
                            stats.shared_accesses += 3 * width as u64;
                            stats.additions += width as u64;
                        }
                        (sj + sn).store(&mut s[j..]);
                        stats.shared_accesses += 3 * width as u64;
                        stats.additions += width as u64;
                        j += width;
                    }
                    while j < upper {
                        if b_set {
                            t[j] = s[j] + t[j + step];
                            stats.shared_accesses += 3;
                            stats.additions += 1;
                        }
                        s[j] += s[j + step];
                        stats.shared_accesses += 3;
                        stats.additions += 1;
                        j += 1;
                    }
                }

                for j in 0..8 {
                    let idx = o + j * stride;
                    if idx < n {
                        g_next[idx] = s[j];
                        h_next[idx] = t[j];
                    }
                }
                stats.global_accesses += 16;
            }
            q += 1;
        }
        g = g_next;
        h = h_next;
        rem >>= 3;
        stride *= 8;
    }
    (h, stats)
}

// ---------------------------------------------------------------------------
// Elementwise epilogues: carrier application and weighted accumulation
// ---------------------------------------------------------------------------

/// Morlet carrier modulation / phase-correction epilogue: refills `out`
/// with `w · (re[i] + i·im[i])` — the §3 scale/phase weight applied to the
/// weighted-bank planes. Two outputs per [`C64x2`] step, with the exact
/// expression of the scalar `w * Complex::new(re, im)` per lane.
pub fn scale_complex_into(
    re: &[f64],
    im: &[f64],
    w: Complex<f64>,
    out: &mut Vec<Complex<f64>>,
) {
    assert_eq!(re.len(), im.len());
    let n = re.len();
    out.clear();
    out.reserve(n);
    let w2 = C64x2::splat(w);
    let pairs = n - n % 2;
    let mut i = 0;
    while i < pairs {
        let z = C64x2 {
            re: [re[i], re[i + 1]],
            im: [im[i], im[i + 1]],
        };
        let p = w2.mul(z);
        out.push(p.lane(0));
        out.push(p.lane(1));
        i += 2;
    }
    if i < n {
        out.push(w * Complex::new(re[i], im[i]));
    }
}

/// f32-tier Morlet carrier epilogue: computes `w · (re[i] + i·im[i])` in
/// f32 — [`C32x4`] lanes carrying the exact expression of the scalar
/// `w * Complex::new(re, im)` per lane — then widens each product *exactly*
/// into the f64 output container the plans hand out. The widening is the
/// only f64 step, so scalar-f32 and SIMD-f32 epilogues stay bit-identical.
pub fn scale_complex_f32_into(
    re: &[f32],
    im: &[f32],
    w: Complex<f32>,
    out: &mut Vec<Complex<f64>>,
) {
    assert_eq!(re.len(), im.len());
    let n = re.len();
    out.clear();
    out.reserve(n);
    let w4 = C32x4::splat(w);
    let quads = n - n % 4;
    let mut i = 0;
    while i < quads {
        let z = C32x4 {
            re: [re[i], re[i + 1], re[i + 2], re[i + 3]],
            im: [im[i], im[i + 1], im[i + 2], im[i + 3]],
        };
        let p = w4.mul(z);
        for t in 0..4 {
            out.push(p.lane(t).cast::<f64>());
        }
        i += 4;
    }
    while i < n {
        out.push((w * Complex::new(re[i], im[i])).cast::<f64>());
        i += 1;
    }
}

/// Weighted accumulation `acc[i] += a · xs[i]` in [`F64x4`] blocks — the
/// Gaussian normalization/reconstruction epilogue (eqs. 13-15, 45-47).
/// Elementwise and single-multiply-single-add per element, so bit-identical
/// to the scalar loop.
pub fn axpy(acc: &mut [f64], a: f64, xs: &[f64]) {
    assert_eq!(acc.len(), xs.len());
    let n = acc.len();
    let a4 = F64x4::splat(a);
    let blocks = n - n % LANES;
    let mut i = 0;
    while i < blocks {
        let v = F64x4::load(&acc[i..]) + a4 * F64x4::load(&xs[i..]);
        v.store(&mut acc[i..]);
        i += LANES;
    }
    while i < n {
        acc[i] += a * xs[i];
        i += 1;
    }
}

/// Complex weighted accumulation `acc[i] += (c[i] + i·s[i]) · w` with a real
/// weight — the separable Gabor row/column epilogue. Exact expression of
/// the scalar `acc[i] += Complex::new(c[i], s[i]).scale(w)` per lane.
pub fn axpy_complex(acc: &mut [Complex<f64>], w: f64, c: &[f64], s: &[f64]) {
    assert_eq!(acc.len(), c.len());
    assert_eq!(acc.len(), s.len());
    let n = acc.len();
    let pairs = n - n % 2;
    let mut i = 0;
    while i < pairs {
        let z = C64x2 {
            re: [c[i], c[i + 1]],
            im: [s[i], s[i + 1]],
        };
        let a = C64x2::from_complex(acc[i], acc[i + 1]).add(z.scale(w));
        acc[i] = a.lane(0);
        acc[i + 1] = a.lane(1);
        i += 2;
    }
    if i < n {
        acc[i] += Complex::new(c[i], s[i]).scale(w);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::gaussian_noise;
    use crate::sft::{asft, kernel_integral};
    use crate::slidingsum;

    #[test]
    fn f64x4_elementwise_ops() {
        let a = F64x4([1.0, 2.0, 3.0, 4.0]);
        let b = F64x4([0.5, -1.0, 2.0, 0.25]);
        assert_eq!((a + b).to_array(), [1.5, 1.0, 5.0, 4.25]);
        assert_eq!((a - b).to_array(), [0.5, 3.0, 1.0, 3.75]);
        assert_eq!((a * b).to_array(), [0.5, -2.0, 6.0, 1.0]);
        assert_eq!((-a).to_array(), [-1.0, -2.0, -3.0, -4.0]);
    }

    #[test]
    fn c64x2_matches_complex_ops() {
        let w = Complex::new(0.3, -1.7);
        let z0 = Complex::new(2.0, 0.5);
        let z1 = Complex::new(-0.25, 4.0);
        let v = C64x2::splat(w).mul(C64x2::from_complex(z0, z1));
        assert_eq!(v.lane(0), w * z0);
        assert_eq!(v.lane(1), w * z1);
        let sc = C64x2::from_complex(z0, z1).scale(1.37);
        assert_eq!(sc.lane(0), z0.scale(1.37));
        assert_eq!(sc.lane(1), z1.scale(1.37));
    }

    #[test]
    fn f32x8_elementwise_ops() {
        let a = F32x8([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = F32x8([0.5, -1.0, 2.0, 0.25, -2.0, 0.5, 1.0, -0.5]);
        assert_eq!(
            (a + b).to_array(),
            [1.5, 1.0, 5.0, 4.25, 3.0, 6.5, 8.0, 7.5]
        );
        assert_eq!(
            (a - b).to_array(),
            [0.5, 3.0, 1.0, 3.75, 7.0, 5.5, 6.0, 8.5]
        );
        assert_eq!(
            (a * b).to_array(),
            [0.5, -2.0, 6.0, 1.0, -10.0, 3.0, 7.0, -4.0]
        );
        assert_eq!(
            (-a).to_array(),
            [-1.0, -2.0, -3.0, -4.0, -5.0, -6.0, -7.0, -8.0]
        );
        assert_eq!(F32x8::splat(2.5).to_array(), [2.5; 8]);
    }

    #[test]
    fn c32x4_matches_complex_ops() {
        let w: Complex<f32> = Complex::new(0.3, -1.7);
        let zs: [Complex<f32>; 4] = [
            Complex::new(2.0, 0.5),
            Complex::new(-0.25, 4.0),
            Complex::new(1.5, -1.5),
            Complex::new(0.0, 2.0),
        ];
        let z = C32x4 {
            re: [zs[0].re, zs[1].re, zs[2].re, zs[3].re],
            im: [zs[0].im, zs[1].im, zs[2].im, zs[3].im],
        };
        let v = C32x4::splat(w).mul(z);
        for (t, &zt) in zs.iter().enumerate() {
            assert_eq!(v.lane(t), w * zt, "lane {t}");
        }
        let sc = z.scale(1.37);
        let ad = z.add(C32x4::splat(w));
        for (t, &zt) in zs.iter().enumerate() {
            assert_eq!(sc.lane(t), zt.scale(1.37), "scale lane {t}");
            assert_eq!(ad.lane(t), zt + w, "add lane {t}");
        }
    }

    #[test]
    fn f32_weighted_bank_bit_identical_to_scalar_f32() {
        let x64 = gaussian_noise(403, 1.0, 22);
        let x: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let k = 23;
        let beta = std::f64::consts::PI / k as f64;
        // 1, 8, 9, and 17 lanes: remainder paths and full F32x8 blocks
        for count in [1usize, 8, 9, 17] {
            let terms: Vec<WeightedTerm> = (0..count)
                .map(|j| WeightedTerm {
                    p: j as f64 + 0.5 * (j % 2) as f64,
                    m: 0.7 - 0.11 * j as f64,
                    l: -0.2 + 0.07 * j as f64,
                })
                .collect();
            let (re_s, im_s) = kernel_integral::weighted_bank(&x, k, beta, &terms);
            let (re_v, im_v) = weighted_bank(&x, k, beta, &terms);
            assert_eq!(re_s, re_v, "re lanes={count}");
            assert_eq!(im_s, im_v, "im lanes={count}");
        }
    }

    #[test]
    fn f32_sliding_sums_bit_identical_to_scalar_f32() {
        let f64s = gaussian_noise(301, 1.0, 45);
        let f: Vec<f32> = f64s.iter().map(|&v| v as f32).collect();
        for l in [0usize, 1, 2, 5, 31, 32, 100, 300, 301, 400] {
            let (h_s, st_s) = slidingsum::sliding_sum_doubling(&f, l);
            let (h_v, st_v) = sliding_sum_doubling(&f, l);
            assert_eq!(h_s, h_v, "doubling l={l}");
            assert_eq!(st_s, st_v, "doubling stats l={l}");
            let (b_s, bs_s) = slidingsum::sliding_sum_blocked(&f, l);
            let (b_v, bs_v) = sliding_sum_blocked(&f, l);
            assert_eq!(b_s, b_v, "blocked l={l}");
            assert_eq!(bs_s, bs_v, "blocked stats l={l}");
        }
    }

    #[test]
    fn scale_complex_f32_matches_scalar_map() {
        let re64 = gaussian_noise(19, 1.0, 15);
        let im64 = gaussian_noise(19, 1.0, 16);
        let re: Vec<f32> = re64.iter().map(|&v| v as f32).collect();
        let im: Vec<f32> = im64.iter().map(|&v| v as f32).collect();
        let w: Complex<f32> = Complex::new(0.83, -0.41);
        let mut out = Vec::new();
        scale_complex_f32_into(&re, &im, w, &mut out);
        for i in 0..19 {
            let want = (w * Complex::new(re[i], im[i])).cast::<f64>();
            assert_eq!(out[i], want, "i={i}");
        }
    }

    #[test]
    fn weighted_bank_bit_identical_to_scalar() {
        let x = gaussian_noise(403, 1.0, 21);
        let k = 23;
        let beta = std::f64::consts::PI / k as f64;
        // 1, 4, 5, and 9 lanes: remainder paths and full blocks
        for count in [1usize, 4, 5, 9] {
            let terms: Vec<WeightedTerm> = (0..count)
                .map(|j| WeightedTerm {
                    p: j as f64 + 0.5 * (j % 2) as f64,
                    m: 0.7 - 0.11 * j as f64,
                    l: -0.2 + 0.07 * j as f64,
                })
                .collect();
            let (re_s, im_s) = kernel_integral::weighted_bank(&x, k, beta, &terms);
            let (re_v, im_v) = weighted_bank(&x, k, beta, &terms);
            assert_eq!(re_s, re_v, "re lanes={count}");
            assert_eq!(im_s, im_v, "im lanes={count}");
        }
    }

    #[test]
    fn weighted_bank_empty_cases() {
        let (re, im) =
            weighted_bank::<f64>(&[], 4, 0.3, &[WeightedTerm { p: 1.0, m: 1.0, l: 1.0 }]);
        assert!(re.is_empty() && im.is_empty());
        let x = [1.0f64, 2.0];
        let (re, im) = weighted_bank(&x, 4, 0.3, &[]);
        assert_eq!(re, vec![0.0, 0.0]);
        assert_eq!(im, vec![0.0, 0.0]);
    }

    #[test]
    fn asft_bank_bit_identical_to_scalar_per_order() {
        let x = gaussian_noise(211, 1.0, 33);
        let (k, alpha) = (14usize, 0.012);
        for orders in [1usize, 3, 4, 7] {
            let ps: Vec<usize> = (0..orders).collect();
            let bank = asft_components_r1_bank(&x, k, &ps, alpha);
            for (j, &p) in ps.iter().enumerate() {
                let want = asft::components_r1(&x, k, p, alpha);
                assert_eq!(bank[j].c, want.c, "c p={p} orders={orders}");
                assert_eq!(bank[j].s, want.s, "s p={p} orders={orders}");
            }
        }
    }

    #[test]
    fn sliding_sums_bit_identical_to_scalar() {
        let f = gaussian_noise(301, 1.0, 44);
        for l in [0usize, 1, 2, 5, 31, 32, 100, 300, 301, 400] {
            let (h_s, st_s) = slidingsum::sliding_sum_doubling(&f, l);
            let (h_v, st_v) = sliding_sum_doubling(&f, l);
            assert_eq!(h_s, h_v, "doubling l={l}");
            assert_eq!(st_s, st_v, "doubling stats l={l}");
            let (b_s, bs_s) = slidingsum::sliding_sum_blocked(&f, l);
            let (b_v, bs_v) = sliding_sum_blocked(&f, l);
            assert_eq!(b_s, b_v, "blocked l={l}");
            assert_eq!(bs_s, bs_v, "blocked stats l={l}");
        }
    }

    #[test]
    fn scale_complex_matches_scalar_map() {
        let re = gaussian_noise(17, 1.0, 5);
        let im = gaussian_noise(17, 1.0, 6);
        let w = Complex::new(0.83, -0.41);
        let mut out = Vec::new();
        scale_complex_into(&re, &im, w, &mut out);
        for i in 0..17 {
            assert_eq!(out[i], w * Complex::new(re[i], im[i]), "i={i}");
        }
    }

    #[test]
    fn axpy_matches_scalar_loop() {
        let xs = gaussian_noise(23, 1.0, 7);
        let mut acc_s = gaussian_noise(23, 1.0, 8);
        let mut acc_v = acc_s.clone();
        let a = -0.77;
        for (o, &v) in acc_s.iter_mut().zip(&xs) {
            *o += a * v;
        }
        axpy(&mut acc_v, a, &xs);
        assert_eq!(acc_s, acc_v);
    }

    #[test]
    fn axpy_complex_matches_scalar_loop() {
        let c = gaussian_noise(19, 1.0, 9);
        let s = gaussian_noise(19, 1.0, 10);
        let w = 0.456;
        let mut acc_s: Vec<Complex<f64>> = (0..19)
            .map(|i| Complex::new(i as f64 * 0.1, -(i as f64) * 0.2))
            .collect();
        let mut acc_v = acc_s.clone();
        for i in 0..19 {
            acc_s[i] += Complex::new(c[i], s[i]).scale(w);
        }
        axpy_complex(&mut acc_v, w, &c, &s);
        assert_eq!(acc_s, acc_v);
    }
}
