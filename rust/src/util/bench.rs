//! Micro-benchmark timer used by `rust/benches/` (criterion is not available
//! offline; this provides the subset the harness needs: warmup, repeated
//! timed runs, and robust statistics).

// Wall-clock reads are this layer's job (it is the benchmark timer) — the workspace-wide
// clippy `disallowed-methods` ban (clippy.toml, masft-lint:
// no-wall-clock-in-core) exists to keep them OUT of the numeric core,
// not out of here.
#![allow(clippy::disallowed_methods)]
use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    /// Case name.
    pub name: String,
    /// Machine-readable configuration tag (backend/precision/shape; empty
    /// when the case has no knobs worth comparing).
    pub config: String,
    /// Output elements produced per iteration (0 = unknown; `ns_per_elem`
    /// then falls back to the per-iteration mean).
    pub elems: usize,
    /// Timed iterations.
    pub iters: usize,
    /// per-iteration wall time, nanoseconds
    pub mean_ns: f64,
    /// Median per-iteration time (ns).
    pub median_ns: f64,
    /// Fastest iteration (ns).
    pub min_ns: f64,
    /// 95th-percentile iteration (ns).
    pub p95_ns: f64,
}

impl Measurement {
    /// Tag this measurement with its configuration and per-iteration output
    /// size, enabling cross-config `ns_per_elem` comparisons in the JSON
    /// report.
    pub fn with_config(mut self, config: &str, elems: usize) -> Self {
        self.config = config.to_string();
        self.elems = elems;
        self
    }

    /// Mean cost per output element (ns); the per-iteration mean when the
    /// case did not declare its output size.
    pub fn ns_per_elem(&self) -> f64 {
        if self.elems > 0 {
            self.mean_ns / self.elems as f64
        } else {
            self.mean_ns
        }
    }

    /// One-line human-readable rendering.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (median {}, min {}, p95 {}, {} iters)",
            self.name,
            super::fmt_ns(self.mean_ns),
            super::fmt_ns(self.median_ns),
            super::fmt_ns(self.min_ns),
            super::fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

/// Benchmark runner with a wall-clock budget per case.
#[derive(Clone, Debug)]
pub struct Bench {
    /// target total measuring time per case (ns)
    pub budget_ns: f64,
    /// number of warmup runs
    pub warmup: usize,
    /// cap on timed iterations
    pub max_iters: usize,
    /// floor on timed iterations
    pub min_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            budget_ns: 2e8, // 200 ms measuring budget
            warmup: 2,
            max_iters: 200,
            min_iters: 5,
        }
    }
}

impl Bench {
    /// Reduced-budget configuration (the `QUICK=1` bench mode).
    pub fn quick() -> Self {
        Self {
            budget_ns: 5e7,
            warmup: 1,
            max_iters: 50,
            min_iters: 3,
        }
    }

    /// Time `f`, returning per-iteration statistics. A `black_box`-style
    /// sink prevents the closure's result from being optimized away.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        // estimate cost with one run
        let t0 = Instant::now();
        std::hint::black_box(f());
        let est = t0.elapsed().as_nanos().max(1) as f64;
        let iters = ((self.budget_ns / est) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let median = samples[samples.len() / 2];
        let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
        Measurement {
            name: name.to_string(),
            config: String::new(),
            elems: 0,
            iters,
            mean_ns: mean,
            median_ns: median,
            min_ns: samples[0],
            p95_ns: p95,
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn entry_json(group: &str, m: &Measurement) -> String {
    format!(
        "{{\"group\":\"{}\",\"name\":\"{}\",\"config\":\"{}\",\"elems\":{},\"iters\":{},\"mean_ns\":{:.1},\"median_ns\":{:.1},\"min_ns\":{:.1},\"p95_ns\":{:.1},\"ns_per_elem\":{:.4}}}",
        json_escape(group),
        json_escape(&m.name),
        json_escape(&m.config),
        m.elems,
        m.iters,
        m.mean_ns,
        m.median_ns,
        m.min_ns,
        m.p95_ns,
        m.ns_per_elem()
    )
}

/// Write (or merge into) a machine-readable benchmark report, e.g.
/// `BENCH_plan.json`: `{"version": 1, "entries": [{group, name, iters,
/// mean_ns, median_ns, min_ns, p95_ns}, …]}`.
///
/// If `path` already holds a report, entries from *other* groups are kept
/// and this group's entries are replaced — so several bench binaries can
/// share one trajectory file and re-runs stay idempotent.
pub fn emit_json(
    path: &std::path::Path,
    group: &str,
    measurements: &[Measurement],
) -> std::io::Result<()> {
    let mut entries: Vec<String> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(root) = crate::util::json::parse(&text) {
            if let Some(arr) = root.get("entries").and_then(|v| v.as_arr()) {
                for e in arr {
                    let g = e.get("group").and_then(|v| v.as_str()).unwrap_or("");
                    if g == group {
                        continue; // replaced below
                    }
                    let m = Measurement {
                        name: e
                            .get("name")
                            .and_then(|v| v.as_str())
                            .unwrap_or("")
                            .to_string(),
                        config: e
                            .get("config")
                            .and_then(|v| v.as_str())
                            .unwrap_or("")
                            .to_string(),
                        elems: e.get("elems").and_then(|v| v.as_usize()).unwrap_or(0),
                        iters: e.get("iters").and_then(|v| v.as_usize()).unwrap_or(0),
                        mean_ns: e.get("mean_ns").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        median_ns: e.get("median_ns").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        min_ns: e.get("min_ns").and_then(|v| v.as_f64()).unwrap_or(0.0),
                        p95_ns: e.get("p95_ns").and_then(|v| v.as_f64()).unwrap_or(0.0),
                    };
                    entries.push(entry_json(g, &m));
                }
            }
        }
    }
    for m in measurements {
        entries.push(entry_json(group, m));
    }
    let body = format!(
        "{{\n\"version\": 1,\n\"entries\": [\n{}\n]\n}}\n",
        entries.join(",\n")
    );
    std::fs::write(path, body)?;
    // Self-check: a report a downstream tool cannot parse is a silent bug
    // factory; fail the emitting bench run instead.
    verify_json(path)
}

/// Verify a `BENCH_*.json` report: it must parse back through
/// [`crate::util::json`] and every entry must carry the comparison fields
/// (`name`, `config`, `ns_per_elem`). [`emit_json`] runs this after every
/// write; bench binaries with private emitters call it on their output too.
pub fn verify_json(path: &std::path::Path) -> std::io::Result<()> {
    let bad = |msg: String| std::io::Error::new(std::io::ErrorKind::InvalidData, msg);
    let text = std::fs::read_to_string(path)?;
    let root = crate::util::json::parse(&text)
        .map_err(|e| bad(format!("{}: emitted JSON does not parse: {e}", path.display())))?;
    let entries = root
        .get("entries")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| bad(format!("{}: report has no entries array", path.display())))?;
    for (i, e) in entries.iter().enumerate() {
        for key in ["name", "config"] {
            if e.get(key).and_then(|v| v.as_str()).is_none() {
                return Err(bad(format!(
                    "{}: entry {i} is missing string field {key:?}",
                    path.display()
                )));
            }
        }
        if e.get("ns_per_elem").and_then(|v| v.as_f64()).is_none() {
            return Err(bad(format!(
                "{}: entry {i} is missing numeric field \"ns_per_elem\"",
                path.display()
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_busy_loop() {
        let b = Bench {
            budget_ns: 1e6,
            warmup: 1,
            max_iters: 20,
            min_iters: 3,
        };
        let m = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.min_ns <= m.median_ns && m.median_ns <= m.p95_ns);
        assert!(m.iters >= 3);
    }

    #[test]
    fn report_contains_name() {
        let b = Bench::quick();
        let m = b.run("noop", || 1 + 1);
        assert!(m.report().contains("noop"));
    }

    #[test]
    fn emit_json_writes_and_merges_groups() {
        let dir = std::env::temp_dir().join(format!("masft_bench_json_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let m1 = Measurement {
            name: "case a".into(),
            config: "backend=simd".into(),
            elems: 50,
            iters: 5,
            mean_ns: 100.0,
            median_ns: 90.0,
            min_ns: 80.0,
            p95_ns: 120.0,
        };
        emit_json(&path, "group1", std::slice::from_ref(&m1)).unwrap();
        let m2 = Measurement {
            name: "case \"b\"".into(),
            config: String::new(),
            elems: 0,
            iters: 7,
            mean_ns: 200.0,
            median_ns: 210.0,
            min_ns: 150.0,
            p95_ns: 260.0,
        };
        emit_json(&path, "group2", std::slice::from_ref(&m2)).unwrap();
        // re-emit group1 — must replace, not duplicate
        emit_json(&path, "group1", std::slice::from_ref(&m1)).unwrap();

        let parsed = crate::util::json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(
            parsed.get("version").and_then(|v| v.as_usize()),
            Some(1)
        );
        let entries = parsed.get("entries").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(entries.len(), 2);
        let groups: Vec<&str> = entries
            .iter()
            .filter_map(|e| e.get("group").and_then(|v| v.as_str()))
            .collect();
        assert!(groups.contains(&"group1") && groups.contains(&"group2"));
        let b = entries
            .iter()
            .find(|e| e.get("group").and_then(|v| v.as_str()) == Some("group2"))
            .unwrap();
        assert_eq!(b.get("name").and_then(|v| v.as_str()), Some("case \"b\""));
        assert_eq!(b.get("median_ns").and_then(|v| v.as_f64()), Some(210.0));
        // no declared output size -> ns_per_elem falls back to the mean
        assert_eq!(b.get("ns_per_elem").and_then(|v| v.as_f64()), Some(200.0));
        let a = entries
            .iter()
            .find(|e| e.get("group").and_then(|v| v.as_str()) == Some("group1"))
            .unwrap();
        assert_eq!(a.get("config").and_then(|v| v.as_str()), Some("backend=simd"));
        assert_eq!(a.get("ns_per_elem").and_then(|v| v.as_f64()), Some(2.0));
        verify_json(&path).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn verify_json_rejects_field_free_reports() {
        let dir = std::env::temp_dir().join(format!("masft_bench_verify_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_bad.json");
        std::fs::write(
            &path,
            "{\n\"version\": 1,\n\"entries\": [\n{\"group\":\"g\",\"name\":\"x\"}\n]\n}\n",
        )
        .unwrap();
        assert!(verify_json(&path).is_err());
        std::fs::write(&path, "not json at all").unwrap();
        assert!(verify_json(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
