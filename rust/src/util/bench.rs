//! Micro-benchmark timer used by `rust/benches/` (criterion is not available
//! offline; this provides the subset the harness needs: warmup, repeated
//! timed runs, and robust statistics).

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    /// per-iteration wall time, nanoseconds
    pub mean_ns: f64,
    pub median_ns: f64,
    pub min_ns: f64,
    pub p95_ns: f64,
}

impl Measurement {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12}/iter  (median {}, min {}, p95 {}, {} iters)",
            self.name,
            super::fmt_ns(self.mean_ns),
            super::fmt_ns(self.median_ns),
            super::fmt_ns(self.min_ns),
            super::fmt_ns(self.p95_ns),
            self.iters
        )
    }
}

/// Benchmark runner with a wall-clock budget per case.
#[derive(Clone, Debug)]
pub struct Bench {
    /// target total measuring time per case (ns)
    pub budget_ns: f64,
    /// number of warmup runs
    pub warmup: usize,
    /// cap on timed iterations
    pub max_iters: usize,
    /// floor on timed iterations
    pub min_iters: usize,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            budget_ns: 2e8, // 200 ms measuring budget
            warmup: 2,
            max_iters: 200,
            min_iters: 5,
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            budget_ns: 5e7,
            warmup: 1,
            max_iters: 50,
            min_iters: 3,
        }
    }

    /// Time `f`, returning per-iteration statistics. A `black_box`-style
    /// sink prevents the closure's result from being optimized away.
    pub fn run<R>(&self, name: &str, mut f: impl FnMut() -> R) -> Measurement {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        // estimate cost with one run
        let t0 = Instant::now();
        std::hint::black_box(f());
        let est = t0.elapsed().as_nanos().max(1) as f64;
        let iters = ((self.budget_ns / est) as usize)
            .clamp(self.min_iters, self.max_iters);

        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let median = samples[samples.len() / 2];
        let p95 = samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)];
        Measurement {
            name: name.to_string(),
            iters,
            mean_ns: mean,
            median_ns: median,
            min_ns: samples[0],
            p95_ns: p95,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_a_known_busy_loop() {
        let b = Bench {
            budget_ns: 1e6,
            warmup: 1,
            max_iters: 20,
            min_iters: 3,
        };
        let m = b.run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.min_ns <= m.median_ns && m.median_ns <= m.p95_ns);
        assert!(m.iters >= 3);
    }

    #[test]
    fn report_contains_name() {
        let b = Bench::quick();
        let m = b.run("noop", || 1 + 1);
        assert!(m.report().contains("noop"));
    }
}
