//! In-tree infrastructure substrates (this image ships no general crate
//! registry, so the library carries its own): a JSON parser for the artifact
//! manifest, a micro-benchmark timer used by `rust/benches/`, SHA-256 for
//! the artifact integrity gate, and small shared helpers.

pub mod bench;
pub mod json;
pub mod sha256;

/// Format a nanosecond count human-readably.
pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::fmt_ns;

    #[test]
    fn formats_scale() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
        assert_eq!(fmt_ns(2.5e6), "2.500 ms");
        assert_eq!(fmt_ns(3.2e9), "3.200 s");
    }
}
