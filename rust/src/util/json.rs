//! Minimal recursive-descent JSON parser — just enough for
//! `artifacts/manifest.json` (objects, arrays, strings, numbers, bools,
//! null; `\uXXXX` escapes; no trailing commas). ~RFC 8259 subset.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (f64, the JSON number model).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// JSON object (sorted keys).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object member lookup (`None` on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric payload, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// The numeric payload as a non-negative integer, if exact.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|v| {
            if v >= 0.0 && v.fract() == 0.0 {
                Some(v as usize)
            } else {
                None
            }
        })
    }

    /// The array payload, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    /// Byte offset of the error.
    pub pos: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document.
pub fn parse(input: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let code = self.hex4()?;
                        // surrogate pair handling
                        let ch = if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        if start + len > self.bytes.len() {
                            return Err(self.err("truncated UTF-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..start + len])
                            .map_err(|_| self.err("invalid UTF-8"))?;
                        out.push_str(s);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-12.5e2").unwrap(), Value::Num(-1250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_usize(), Some(1));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn escapes_and_unicode() {
        let v = parse(r#""a\n\t\"é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"é😀"));
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo — ok"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Value::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Value::Obj(Default::default()));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = parse(" {\n\t\"k\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("k").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(parse("1.5").unwrap().as_usize(), None);
        assert_eq!(parse("-3").unwrap().as_usize(), None);
        assert_eq!(parse("42").unwrap().as_usize(), Some(42));
    }
}
