//! Gaussian scale space and scale-normalized blob detection — the classic
//! downstream consumer (SIFT/SURF-style, paper refs [9]-[12], [17]) whose
//! cost the paper's O(P·N) smoothing makes independent of scale.
//!
//! A scale space needs smoothing at many σ, several of them large; with
//! direct convolution the cost per level grows linearly in σ, with the SFT
//! path every level costs the same. [`ScaleSpace`] builds the stack and
//! finds 3D (x, y, σ) extrema of the scale-normalized Laplacian
//! `σ²·∇²G ⊛ I`, the standard blob detector.

use super::{Image, ImageSmoother};
use crate::exec::Parallelism;
use crate::plan::Backend;
use crate::Result;

/// Options for the scale-space pyramid.
#[derive(Clone, Debug)]
pub struct ScaleSpaceOptions {
    /// smallest σ
    pub sigma0: f64,
    /// multiplicative step between levels
    pub step: f64,
    /// number of levels
    pub levels: usize,
    /// SFT order per level
    pub p: usize,
    /// worker fan-out of each level's separable passes (bit-identical)
    pub parallelism: Parallelism,
    /// execution backend of each level's separable passes (bit-identical;
    /// see [`ImageSmoother::with_backend`])
    pub backend: Backend,
}

impl Default for ScaleSpaceOptions {
    fn default() -> Self {
        Self {
            sigma0: 2.0,
            step: std::f64::consts::SQRT_2,
            levels: 6,
            p: 6,
            parallelism: Parallelism::Auto,
            backend: Backend::PureRust,
        }
    }
}

/// A stack of scale-normalized Laplacian responses.
#[derive(Clone, Debug)]
pub struct ScaleSpace {
    /// σ of each level, ascending.
    pub sigmas: Vec<f64>,
    /// Scale-normalized LoG response per level.
    pub log_levels: Vec<Image>,
}

/// One detected blob.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Blob {
    /// Pixel x of the extremum.
    pub x: usize,
    /// Pixel y of the extremum.
    pub y: usize,
    /// Scale (σ) of the level the extremum lives on.
    pub sigma: f64,
    /// |scale-normalized LoG| at the extremum
    pub strength: f64,
}

impl ScaleSpace {
    /// Build the scale-normalized LoG stack of `img`.
    pub fn build(img: &Image, opts: &ScaleSpaceOptions) -> Result<Self> {
        anyhow::ensure!(opts.levels >= 1, "need at least one level");
        anyhow::ensure!(opts.step > 1.0, "step must be > 1");
        let mut sigmas = Vec::with_capacity(opts.levels);
        let mut log_levels = Vec::with_capacity(opts.levels);
        let mut sigma = opts.sigma0;
        for _ in 0..opts.levels {
            let sm = ImageSmoother::new(sigma, opts.p)?
                .with_parallelism(opts.parallelism)
                .with_backend(opts.backend);
            let mut log = sm.laplacian(img);
            // scale normalization: σ²·∇²
            let s2 = sigma * sigma;
            for y in 0..log.height {
                for x in 0..log.width {
                    log.set(x, y, s2 * log.get(x, y));
                }
            }
            sigmas.push(sigma);
            log_levels.push(log);
            sigma *= opts.step;
        }
        Ok(Self { sigmas, log_levels })
    }

    /// 3D local extrema of |LoG| above `threshold`, excluding an edge margin
    /// proportional to each level's σ (window support).
    ///
    /// Choose `threshold` above the fitted-D2 DC leakage floor: a constant
    /// image of unit intensity leaves a scale-normalized residual of about
    /// 0.05 at P = 6 (the e(G_DD) fit error of paper Table 1 surfacing in
    /// 2D), while a matched unit-amplitude blob responds at ≈0.5.
    pub fn detect_blobs(&self, threshold: f64) -> Vec<Blob> {
        let mut blobs = Vec::new();
        let levels = self.log_levels.len();
        for li in 0..levels {
            let level = &self.log_levels[li];
            let margin = (3.0 * self.sigmas[li]).ceil() as usize + 1;
            if 2 * margin + 2 >= level.width || 2 * margin + 2 >= level.height {
                continue;
            }
            for y in margin..level.height - margin {
                for x in margin..level.width - margin {
                    let v = level.get(x, y);
                    // NaN fails every `<` test, so it must be rejected
                    // explicitly or it would sail through both the
                    // threshold and the extremum comparisons
                    if v.is_nan() || v.abs() < threshold {
                        continue;
                    }
                    if self.is_extremum(li, x, y) {
                        blobs.push(Blob {
                            x,
                            y,
                            sigma: self.sigmas[li],
                            strength: v.abs(),
                        });
                    }
                }
            }
        }
        // total_cmp: even if a NaN strength slipped in, sorting must not
        // panic the whole detection pass (partial_cmp().unwrap() did)
        blobs.sort_by(|a, b| b.strength.total_cmp(&a.strength));
        blobs
    }

    /// |v| strictly dominates its 3×3 spatial neighbourhood at the level and
    /// the same pixel on adjacent levels (sign-consistent extremum).
    fn is_extremum(&self, li: usize, x: usize, y: usize) -> bool {
        let v = self.log_levels[li].get(x, y);
        let va = v.abs();
        for dy in -1i64..=1 {
            for dx in -1i64..=1 {
                if dx == 0 && dy == 0 {
                    continue;
                }
                let nx = (x as i64 + dx) as usize;
                let ny = (y as i64 + dy) as usize;
                let u = self.log_levels[li].get(nx, ny);
                if u.abs() >= va || u * v < 0.0 && u.abs() >= va {
                    return false;
                }
            }
        }
        for adj in [li.wrapping_sub(1), li + 1] {
            if adj < self.log_levels.len() {
                if self.log_levels[adj].get(x, y).abs() >= va {
                    return false;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A blob of scale s at (cx, cy).
    fn blob_image(w: usize, h: usize, blobs: &[(f64, f64, f64)]) -> Image {
        Image::from_fn(w, h, |x, y| {
            blobs
                .iter()
                .map(|&(cx, cy, s)| {
                    let dx = x as f64 - cx;
                    let dy = y as f64 - cy;
                    (-(dx * dx + dy * dy) / (2.0 * s * s)).exp()
                })
                .sum()
        })
    }

    #[test]
    fn single_blob_detected_at_right_scale_and_place() {
        // LoG responds maximally at σ ≈ blob scale
        let s = 6.0;
        let img = blob_image(128, 128, &[(64.0, 64.0, s)]);
        let ss = ScaleSpace::build(
            &img,
            &ScaleSpaceOptions {
                sigma0: 3.0,
                step: std::f64::consts::SQRT_2,
                levels: 5,
                p: 6,
                ..Default::default()
            },
        )
        .unwrap();
        let blobs = ss.detect_blobs(0.05);
        assert!(!blobs.is_empty(), "no blobs found");
        let top = blobs[0];
        assert!((top.x as f64 - 64.0).abs() <= 2.0, "x={}", top.x);
        assert!((top.y as f64 - 64.0).abs() <= 2.0, "y={}", top.y);
        // detected scale within one pyramid step of the true scale
        assert!(
            top.sigma / s < std::f64::consts::SQRT_2 && s / top.sigma < std::f64::consts::SQRT_2,
            "sigma={} true={}",
            top.sigma,
            s
        );
    }

    #[test]
    fn two_blobs_of_different_scales() {
        let img = blob_image(160, 96, &[(40.0, 48.0, 4.0), (116.0, 48.0, 9.0)]);
        let ss = ScaleSpace::build(
            &img,
            &ScaleSpaceOptions {
                sigma0: 2.8,
                step: 1.5,
                levels: 5,
                p: 6,
                ..Default::default()
            },
        )
        .unwrap();
        let blobs = ss.detect_blobs(0.05);
        // the two strongest detections split between the two centres
        let near = |b: &Blob, cx: f64| (b.x as f64 - cx).abs() < 6.0;
        assert!(
            blobs.iter().take(4).any(|b| near(b, 40.0)),
            "small blob missed: {blobs:?}"
        );
        assert!(
            blobs.iter().take(4).any(|b| near(b, 116.0)),
            "large blob missed: {blobs:?}"
        );
        // and the larger blob is found at a larger σ
        let s_small = blobs.iter().find(|b| near(b, 40.0)).unwrap().sigma;
        let s_large = blobs.iter().find(|b| near(b, 116.0)).unwrap().sigma;
        assert!(s_large > s_small, "{s_large} vs {s_small}");
    }

    #[test]
    fn flat_image_has_no_blobs() {
        // residual LoG on a constant image is the D2 fit's DC leakage
        // (≈0.05 after σ² normalization — see detect_blobs docs); any
        // real blob responds at ~10x that
        let img = Image::from_fn(96, 96, |_, _| 1.0);
        let ss = ScaleSpace::build(&img, &ScaleSpaceOptions::default()).unwrap();
        assert!(ss.detect_blobs(0.1).is_empty());
    }

    #[test]
    fn options_validated() {
        let img = Image::zeros(32, 32);
        assert!(ScaleSpace::build(
            &img,
            &ScaleSpaceOptions {
                levels: 0,
                ..Default::default()
            }
        )
        .is_err());
        assert!(ScaleSpace::build(
            &img,
            &ScaleSpaceOptions {
                step: 0.9,
                ..Default::default()
            }
        )
        .is_err());
    }

    #[test]
    fn normalization_makes_response_scale_covariant() {
        // same blob at two sizes: the peak |σ²LoG| should be comparable
        let img_a = blob_image(128, 128, &[(64.0, 64.0, 4.0)]);
        let img_b = blob_image(128, 128, &[(64.0, 64.0, 8.0)]);
        let opts = ScaleSpaceOptions {
            sigma0: 4.0,
            step: std::f64::consts::SQRT_2,
            levels: 4,
            p: 6,
            ..Default::default()
        };
        let pa = ScaleSpace::build(&img_a, &opts)
            .unwrap()
            .detect_blobs(0.01)
            .first()
            .map(|b| b.strength)
            .unwrap_or(0.0);
        let pb = ScaleSpace::build(&img_b, &opts)
            .unwrap()
            .detect_blobs(0.01)
            .first()
            .map(|b| b.strength)
            .unwrap_or(0.0);
        assert!(pa > 0.0 && pb > 0.0);
        assert!(pa / pb < 2.0 && pb / pa < 2.0, "{pa} vs {pb}");
    }
}
