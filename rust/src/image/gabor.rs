//! Oriented 2D Gabor filtering from separable SFT passes.
//!
//! A 2D Gabor filter `g(x,y) = G_σ(x,y)·e^{iω(x cosθ + y sinθ)}` is not
//! separable for arbitrary θ, but the axis-aligned factorization
//!
//! ```text
//! g(x, y) = [G_σ(x)e^{iω_x x}] ⊗ [G_σ(y)e^{iω_y y}],   (ω_x, ω_y) = ω(cosθ, sinθ)
//! ```
//!
//! *is* exact for an isotropic envelope — each factor is a 1D Morlet-style
//! kernel the SFT machinery computes in O(P) per sample (the paper's §3
//! transform with ξ/σ = ω_x or ω_y and κ = 0). This module implements that
//! two-pass complex filtering and a small multi-orientation bank on top,
//! the texture/feature-extraction application the paper's introduction
//! cites for Gabor wavelets ([2], [3]).

use super::Image;
use crate::dsp::Complex;
use crate::exec::{self, Parallelism};
use crate::plan::Backend;
use crate::sft;
use crate::Result;

/// Complex response plane of one Gabor filter.
#[derive(Clone, Debug)]
pub struct GaborResponse {
    /// Real response plane.
    pub re: Image,
    /// Imaginary response plane.
    pub im: Image,
}

impl GaborResponse {
    /// Pointwise magnitude (texture energy).
    pub fn magnitude(&self) -> Image {
        let mut out = Image::zeros(self.re.width, self.re.height);
        for y in 0..out.height {
            for x in 0..out.width {
                let r = self.re.get(x, y);
                let i = self.im.get(x, y);
                out.set(x, y, (r * r + i * i).sqrt());
            }
        }
        out
    }
}

/// One 1D complex Gabor factor `G_σ(t)·e^{iωt}` prepared as SFT fits:
/// cos-series on the even part `G cos(ωt)` and on `G sin(ωt)`'s odd
/// companion (fitted with a sin bank through the real-frequency SFT).
#[derive(Clone, Debug)]
struct Factor1D {
    /// envelope cos-series coefficients a_p (orders 0..=P)
    a: Vec<f64>,
    omega: f64,
    k: usize,
    beta: f64,
}

impl Factor1D {
    fn new(sigma: f64, omega: f64, p: usize) -> Result<Self> {
        // parameter checks live in plan::spec, shared with every other
        // constructor in the crate
        crate::plan::spec::check_sigma(sigma)?;
        crate::plan::spec::check_order(p, "envelope order P")?;
        let k = (3.0 * sigma).ceil() as usize;
        let beta = std::f64::consts::PI / k as f64;
        // The envelope cos-series comes from the process-wide fit cache
        // (least squares is linear in its target, so the *normalized*
        // envelope G_σ — unit DC gain, comparable magnitude across
        // orientations — is the cached unnormalized fit scaled by amp).
        let gamma = 1.0 / (2.0 * sigma * sigma);
        let amp = (gamma / std::f64::consts::PI).sqrt();
        let a = crate::plan::cache::envelope_fit(sigma, k, p, beta)
            .iter()
            .map(|&v| amp * v)
            .collect();
        Ok(Self { a, omega, k, beta })
    }

    /// Complex filtering of a real row: `y[n] = Σ_k G[k]e^{iωk}·x[n-k]`
    /// via the multiplication identity — the product of the envelope
    /// cos-series with the carrier is a bank of real-frequency SFTs at
    /// ω_p = ω ± βp (paper eq. 60 with κ = 0).
    ///
    /// With [`Backend::Simd`] the per-band weighted accumulation runs
    /// through [`crate::simd::axpy_complex`] — bit-identical to the scalar
    /// loop (each element is the same multiply-accumulate).
    fn filter_row(&self, x: &[f64], backend: Backend) -> Vec<Complex<f64>> {
        let n = x.len();
        let mut acc = vec![Complex::zero(); n];
        for (p, &a_p) in self.a.iter().enumerate() {
            // a_p cos(βpk)e^{iωk} = (a_p/2)(e^{i(ω+βp)k} + e^{i(ω−βp)k}), p>0
            let weights: &[(f64, f64)] = if p == 0 {
                &[(1.0, 0.0)]
            } else {
                &[(0.5, 1.0), (0.5, -1.0)]
            };
            for &(w, sgn) in weights {
                // real-frequency SFT (eqs. 58-59): frequency ω_p expressed
                // as β'·p' with β' = ω_p, p' = 1 — the kernel-integral path
                // supports arbitrary real frequencies.
                let omega_p = self.omega + sgn * self.beta * p as f64;
                let comp = sft::kernel_integral::components(x, self.k, omega_p, 1.0);
                if backend == Backend::Simd {
                    crate::simd::axpy_complex(&mut acc, w * a_p, &comp.c, &comp.s);
                } else {
                    for i in 0..n {
                        // Σ_k e^{iω_p k} x[n−k] = c(ω_p)[n] + i·s(ω_p)[n]
                        acc[i] += Complex::new(comp.c[i], comp.s[i]).scale(w * a_p);
                    }
                }
            }
        }
        acc
    }

    /// Complex filtering of a complex row (second separable pass).
    fn filter_row_complex(&self, x: &[Complex<f64>], backend: Backend) -> Vec<Complex<f64>> {
        let re: Vec<f64> = x.iter().map(|c| c.re).collect();
        let im: Vec<f64> = x.iter().map(|c| c.im).collect();
        let fr = self.filter_row(&re, backend);
        let fi = self.filter_row(&im, backend);
        fr.into_iter()
            .zip(fi)
            .map(|(a, b)| a + Complex::new(-b.im, b.re)) // a + i·b
            .collect()
    }
}

/// A bank of oriented Gabor filters sharing (σ, ω, P). The per-orientation
/// 1-D factors (each an MMSE envelope fit) are prepared once at
/// construction, so repeated [`GaborBank::responses`] /
/// [`crate::plan::Gabor2dPlan`] executions never refit.
#[derive(Clone, Debug)]
pub struct GaborBank {
    /// isotropic envelope width σ (pixels)
    pub sigma: f64,
    /// carrier frequency in radians/pixel
    pub omega: f64,
    /// orientation angles, equally spaced in [0, π)
    pub orientations: Vec<f64>,
    p: usize,
    /// prepared (x-factor, y-factor) per orientation
    factors: Vec<(Factor1D, Factor1D)>,
    /// worker fan-out of the separable row/column passes
    parallelism: Parallelism,
    /// execution backend of the separable passes (bit-identical)
    backend: Backend,
}

impl GaborBank {
    /// `n_orientations` equally spaced in [0, π).
    ///
    /// Validation is delegated to the [`crate::plan::Gabor2dSpec`] builder —
    /// the single home of constructor checks.
    pub fn new(sigma: f64, omega: f64, n_orientations: usize, p: usize) -> Result<Self> {
        let spec = crate::plan::Gabor2dSpec::builder(sigma, omega)
            .orientations(n_orientations)
            .order(p)
            .build()?;
        let orientations = spec.orientation_angles();
        let factors = orientations
            .iter()
            .map(|&th| {
                Ok((
                    Factor1D::new(spec.sigma, spec.omega * th.cos(), spec.p)?,
                    Factor1D::new(spec.sigma, spec.omega * th.sin(), spec.p)?,
                ))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            sigma: spec.sigma,
            omega: spec.omega,
            orientations,
            p: spec.p,
            factors,
            parallelism: spec.parallelism,
            backend: spec.backend,
        })
    }

    /// Set the worker fan-out of the separable passes (rows, then columns).
    /// Output is bit-identical for any setting.
    pub fn with_parallelism(mut self, parallelism: Parallelism) -> Self {
        self.parallelism = parallelism;
        self
    }

    /// Select the execution backend of the separable passes
    /// ([`Backend::Simd`] vectorizes the per-band accumulation;
    /// bit-identical output for any setting).
    pub fn with_backend(mut self, backend: Backend) -> Self {
        // Backend::Auto resolves here (crate::tune): profile row first,
        // shape heuristic on the separable window otherwise.
        self.backend = crate::tune::resolve_backend(
            crate::tune::Workload::Gabor2d,
            (3.0 * self.sigma).ceil() as usize,
            backend,
        );
        self
    }

    /// Filter with one orientation θ (radians). Bank orientations use the
    /// factors prepared at construction; arbitrary angles build theirs on
    /// the fly (the envelope fit still comes from the process-wide cache).
    pub fn response(&self, img: &Image, theta: f64) -> Result<GaborResponse> {
        if let Some(i) = self.orientations.iter().position(|&o| o == theta) {
            let (fx, fy) = &self.factors[i];
            return Ok(self.response_with(img, fx, fy));
        }
        let fx = Factor1D::new(self.sigma, self.omega * theta.cos(), self.p)?;
        let fy = Factor1D::new(self.sigma, self.omega * theta.sin(), self.p)?;
        Ok(self.response_with(img, &fx, &fy))
    }

    fn response_with(&self, img: &Image, fx: &Factor1D, fy: &Factor1D) -> GaborResponse {
        let mut plane = Vec::new();
        let mut t = Vec::new();
        self.response_into(img, fx, fy, &mut plane, &mut t)
    }

    /// One orientation with caller-owned intermediate buffers, so a bank
    /// run ([`GaborBank::responses`]) reuses two image-sized planes across
    /// all orientations instead of reallocating them per orientation.
    fn response_into(
        &self,
        img: &Image,
        fx: &Factor1D,
        fy: &Factor1D,
        plane: &mut Vec<Complex<f64>>,
        t: &mut Vec<Complex<f64>>,
    ) -> GaborResponse {
        let (w, h) = (img.width, img.height);
        // pass 1: rows (x direction), real input → complex plane; each row
        // is an independent 1-D filtering, fanned out across workers
        // (every element is fully overwritten, so no re-zeroing on reuse)
        plane.resize(w * h, Complex::zero());
        let backend = self.backend;
        if w > 0 {
            exec::for_each_chunk(self.parallelism, plane, w, || (), |y, row_out, _| {
                row_out.copy_from_slice(&fx.filter_row(img.row(y), backend));
            });
        }
        // pass 2: columns (y direction) on the transposed complex plane —
        // columns are likewise independent
        t.resize(w * h, Complex::zero());
        for y in 0..h {
            for x in 0..w {
                t[x * h + y] = plane[y * w + x];
            }
        }
        if h > 0 {
            exec::for_each_chunk(self.parallelism, t, h, || (), |_x, col, _| {
                let filtered = fy.filter_row_complex(col, backend);
                col.copy_from_slice(&filtered);
            });
        }
        let mut re = Image::zeros(w, h);
        let mut im = Image::zeros(w, h);
        for x in 0..w {
            for y in 0..h {
                let v = t[x * h + y];
                re.set(x, y, v.re);
                im.set(x, y, v.im);
            }
        }
        GaborResponse { re, im }
    }

    /// All orientations; index i corresponds to `self.orientations[i]`.
    /// The two image-sized intermediate planes are shared across the whole
    /// bank run.
    pub fn responses(&self, img: &Image) -> Result<Vec<GaborResponse>> {
        let mut plane = Vec::new();
        let mut t = Vec::new();
        Ok(self
            .factors
            .iter()
            .map(|(fx, fy)| self.response_into(img, fx, fy, &mut plane, &mut t))
            .collect())
    }

    /// Per-pixel argmax orientation of the magnitude responses — the
    /// dominant local texture direction.
    pub fn orientation_map(&self, img: &Image) -> Result<Image> {
        let mags: Vec<Image> = self
            .responses(img)?
            .into_iter()
            .map(|r| r.magnitude())
            .collect();
        let mut out = Image::zeros(img.width, img.height);
        for y in 0..img.height {
            for x in 0..img.width {
                let mut best = (0usize, f64::NEG_INFINITY);
                for (i, m) in mags.iter().enumerate() {
                    if m.get(x, y) > best.1 {
                        best = (i, m.get(x, y));
                    }
                }
                out.set(x, y, self.orientations[best.0]);
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Oriented grating: cos(ω(x cosθ + y sinθ)).
    fn grating(w: usize, h: usize, omega: f64, theta: f64) -> Image {
        Image::from_fn(w, h, |x, y| {
            (omega * (x as f64 * theta.cos() + y as f64 * theta.sin())).cos()
        })
    }

    #[test]
    fn factor_matches_direct_convolution() {
        // 1D check: the multiplication-identity filtering equals the O(KN)
        // complex convolution with G_σ e^{iωk}.
        let (sigma, omega, p) = (5.0, 0.35, 5);
        let f = Factor1D::new(sigma, omega, p).unwrap();
        let n = 256;
        let x: Vec<f64> = (0..n).map(|i| (0.2 * i as f64).sin() + 0.3).collect();
        let got = f.filter_row(&x, Backend::PureRust);
        // direct reference
        let gamma = 1.0 / (2.0 * sigma * sigma);
        let amp = (gamma / std::f64::consts::PI).sqrt();
        let ki = f.k as isize;
        let mut worst = 0.0f64;
        for i in (f.k)..(n - f.k) {
            let mut want = Complex::zero();
            for kk in -ki..=ki {
                let j = i as isize - kk;
                if j < 0 || j >= n as isize {
                    continue;
                }
                let g = amp * (-gamma * (kk * kk) as f64).exp();
                want += Complex::cis(omega * kk as f64).scale(g * x[j as usize]);
            }
            worst = worst.max((got[i] - want).norm());
        }
        assert!(worst < 2e-3, "max deviation {worst}");
    }

    #[test]
    fn aligned_grating_dominates_orthogonal() {
        let omega = 0.6;
        let bank = GaborBank::new(3.0, omega, 4, 5).unwrap();
        let img = grating(96, 96, omega, 0.0); // horizontal-frequency grating
        let aligned = bank.response(&img, 0.0).unwrap().magnitude();
        let ortho = bank
            .response(&img, std::f64::consts::FRAC_PI_2)
            .unwrap()
            .magnitude();
        let c = 48;
        assert!(
            aligned.get(c, c) > 4.0 * ortho.get(c, c),
            "aligned {} vs ortho {}",
            aligned.get(c, c),
            ortho.get(c, c)
        );
    }

    #[test]
    fn orientation_map_recovers_grating_angle() {
        let omega = 0.6;
        let bank = GaborBank::new(3.0, omega, 4, 5).unwrap();
        let theta = std::f64::consts::PI / 4.0;
        let img = grating(96, 96, omega, theta);
        let omap = bank.orientation_map(&img).unwrap();
        // interior pixels should pick the π/4 bucket
        let mut hits = 0;
        let mut total = 0;
        for y in 30..66 {
            for x in 30..66 {
                total += 1;
                if (omap.get(x, y) - theta).abs() < 1e-9 {
                    hits += 1;
                }
            }
        }
        assert!(
            hits as f64 > 0.9 * total as f64,
            "{hits}/{total} pixels picked θ=π/4"
        );
    }

    #[test]
    fn bank_validates_inputs() {
        assert!(GaborBank::new(3.0, 0.5, 0, 5).is_err());
        assert!(GaborBank::new(3.0, 4.0, 4, 5).is_err()); // above Nyquist
        assert!(Factor1D::new(-1.0, 0.2, 4).is_err());
    }

    #[test]
    fn magnitude_is_shift_covariant_for_grating() {
        // |Gabor response| of a pure grating is ~constant in the interior
        let omega = 0.5;
        let bank = GaborBank::new(4.0, omega, 1, 5).unwrap();
        let img = grating(128, 64, omega, 0.0);
        let mag = bank.response(&img, 0.0).unwrap().magnitude();
        let vals: Vec<f64> = (40..88).map(|x| mag.get(x, 32)).collect();
        let mean = vals.iter().sum::<f64>() / vals.len() as f64;
        for v in vals {
            assert!((v - mean).abs() < 0.05 * mean, "{v} vs mean {mean}");
        }
    }
}
