//! 2D image filtering by separable SFT — the paper's §4 opening case:
//! "When an image of size N_X × N_Y is filtered, lines in the image are
//! independently calculated; hence calculation time is O(P(N_X + N_Y))".
//!
//! Everything here is built from the 1D machinery ([`crate::gaussian`],
//! [`crate::sft`]) applied along rows and then columns:
//!
//! * [`Image`] — a minimal row-major f64 image container.
//! * [`ImageSmoother`] — separable Gaussian smoothing, first derivatives
//!   (gradient), and the Laplacian-of-Gaussian, each in O(P·N_pixels)
//!   independent of σ.
//! * [`GaborBank`] — oriented 2D Gabor filtering assembled from separable
//!   x/y Morlet/Gaussian passes (the image-processing application the
//!   paper's intro cites for Gabor wavelets).
//!
//! The separable identity used throughout: for kernels g (smoothing) and
//! g' (derivative), `∂x (G ⊛ I) = g'_x ⊛ (g_y ⊛ I)` — every pass is a 1D
//! window convolution the SFT computes in O(P) per sample.

mod gabor;
mod scale_space;

pub use gabor::{GaborBank, GaborResponse};
pub use scale_space::{ScaleSpace, ScaleSpaceOptions};

use crate::exec::{self, Parallelism};
use crate::gaussian::GaussianSmoother;
use crate::plan::Backend;
use crate::sft::Algorithm;
use crate::Result;

/// Row-major f64 image.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    /// Width in pixels.
    pub width: usize,
    /// Height in pixels.
    pub height: usize,
    data: Vec<f64>,
}

impl Image {
    /// Zero image of the given size.
    pub fn zeros(width: usize, height: usize) -> Self {
        Self {
            width,
            height,
            data: vec![0.0; width * height],
        }
    }

    /// Wrap existing row-major data (len must equal width·height).
    pub fn from_vec(width: usize, height: usize, data: Vec<f64>) -> Result<Self> {
        anyhow::ensure!(
            data.len() == width * height,
            "data length {} != {}x{}",
            data.len(),
            width,
            height
        );
        Ok(Self {
            width,
            height,
            data,
        })
    }

    /// Build from a function of (x, y).
    pub fn from_fn(width: usize, height: usize, f: impl Fn(usize, usize) -> f64) -> Self {
        let mut img = Self::zeros(width, height);
        for y in 0..height {
            for x in 0..width {
                img.data[y * width + x] = f(x, y);
            }
        }
        img
    }

    /// Pixel value at (x, y).
    #[inline]
    pub fn get(&self, x: usize, y: usize) -> f64 {
        self.data[y * self.width + x]
    }

    /// Set the pixel at (x, y).
    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: f64) {
        self.data[y * self.width + x] = v;
    }

    /// Immutable view of one row.
    pub fn row(&self, y: usize) -> &[f64] {
        &self.data[y * self.width..(y + 1) * self.width]
    }

    /// Copy one column out (columns are strided in row-major layout).
    pub fn column(&self, x: usize, out: &mut Vec<f64>) {
        out.clear();
        out.extend((0..self.height).map(|y| self.data[y * self.width + x]));
    }

    /// Raw row-major data.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// Transpose (used to reuse the row pass for columns cache-coherently).
    pub fn transpose(&self) -> Image {
        let mut t = Image::zeros(self.height, self.width);
        for y in 0..self.height {
            for x in 0..self.width {
                t.data[x * self.height + y] = self.data[y * self.width + x];
            }
        }
        t
    }

    /// Max |a - b| over all pixels (images must be the same size).
    pub fn max_abs_diff(&self, other: &Image) -> f64 {
        assert_eq!(self.width, other.width);
        assert_eq!(self.height, other.height);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Relative RMSE against `exact` over an interior margin (edge effects
    /// from the window extension are excluded, as in the 1D harnesses).
    pub fn interior_rel_rmse(&self, exact: &Image, margin: usize) -> f64 {
        assert_eq!(self.width, exact.width);
        assert_eq!(self.height, exact.height);
        let (mut num, mut den) = (0.0, 0.0);
        for y in margin..self.height.saturating_sub(margin) {
            for x in margin..self.width.saturating_sub(margin) {
                let d = self.get(x, y) - exact.get(x, y);
                num += d * d;
                den += exact.get(x, y) * exact.get(x, y);
            }
        }
        if den == 0.0 {
            return if num == 0.0 { 0.0 } else { f64::INFINITY };
        }
        (num / den).sqrt()
    }
}

/// Which separable pass to run along an axis.
#[derive(Copy, Clone, Debug, PartialEq)]
enum Pass {
    Smooth,
    D1,
    D2,
}

/// Separable 2D Gaussian filtering via 1D SFT passes.
///
/// Complexity is O(P·W·H) regardless of σ — the paper's 2D argument — and
/// every pass reuses one [`GaussianSmoother`] (one MMSE fit per σ).
///
/// Rows (and, via transpose, columns) are mutually independent 1-D
/// filterings, so each pass fans them out across workers per
/// [`ImageSmoother::with_parallelism`]; output is bit-identical to
/// sequential for any worker count.
#[derive(Clone, Debug)]
pub struct ImageSmoother {
    smoother: GaussianSmoother,
    algorithm: Algorithm,
    parallelism: Parallelism,
    backend: Backend,
}

impl ImageSmoother {
    /// σ and SFT order P as in [`GaussianSmoother::new`].
    pub fn new(sigma: f64, p: usize) -> Result<Self> {
        Ok(Self {
            smoother: GaussianSmoother::new(sigma, p)?,
            algorithm: Algorithm::KernelIntegral,
            parallelism: Parallelism::Auto,
            backend: Backend::PureRust,
        })
    }

    /// Switch the 1D component algorithm (kernel integral / recursive).
    pub fn with_algorithm(mut self, algorithm: Algorithm) -> Self {
        self.algorithm = algorithm;
        self
    }

    /// Set the worker fan-out of the separable row/column passes.
    pub fn with_parallelism(mut self, parallelism: Parallelism) -> Self {
        self.parallelism = parallelism;
        self
    }

    /// Select the execution backend of the separable 1-D passes.
    /// [`Backend::Simd`] routes each row/column through the vectorized
    /// fused bank ([`crate::simd`]) when the algorithm is the kernel
    /// integral — output **bit-identical** to the scalar path, and it
    /// composes with [`ImageSmoother::with_parallelism`]. Other algorithms
    /// and [`Backend::Runtime`] fall back to the scalar reference.
    pub fn with_backend(mut self, backend: Backend) -> Self {
        // Backend::Auto resolves here (crate::tune): profile row first,
        // shape heuristic on the 1-D pass's window otherwise.
        self.backend = crate::tune::resolve_backend(
            crate::tune::Workload::GaussianSmooth,
            self.smoother.k,
            backend,
        );
        self
    }

    /// Window half-width of the underlying 1D smoother.
    pub fn k(&self) -> usize {
        self.smoother.k
    }

    /// Apply `f` to every row of `img` independently (parallel over rows),
    /// writing each filtered row into the output image.
    fn run_rows_with(&self, img: &Image, f: impl Fn(&[f64]) -> Vec<f64> + Sync) -> Image {
        let mut out = Image::zeros(img.width, img.height);
        if img.width == 0 || img.height == 0 {
            return out;
        }
        exec::for_each_chunk(
            self.parallelism,
            &mut out.data,
            img.width,
            || (),
            |y, row_out, _| {
                row_out.copy_from_slice(&f(img.row(y)));
            },
        );
        out
    }

    fn run_axis_rows(&self, img: &Image, pass: Pass) -> Image {
        if self.backend == Backend::Simd && self.algorithm == Algorithm::KernelIntegral {
            // vectorized fused bank per row — bit-identical to the scalar
            // kernel-integral path (rust/tests/simd_parity.rs)
            return self.run_rows_with(img, |row| match pass {
                Pass::Smooth => self.smoother.smooth_simd(row),
                Pass::D1 => self.smoother.derivative1_simd(row),
                Pass::D2 => self.smoother.derivative2_simd(row),
            });
        }
        self.run_rows_with(img, |row| match pass {
            Pass::Smooth => self.smoother.smooth_with(self.algorithm, row),
            Pass::D1 => self.smoother.derivative1_with(self.algorithm, row),
            Pass::D2 => self.smoother.derivative2_with(self.algorithm, row),
        })
    }

    /// One separable application: `pass_x` along rows, `pass_y` along
    /// columns (via transpose for cache-coherent row access).
    fn separable(&self, img: &Image, pass_x: Pass, pass_y: Pass) -> Image {
        let rows_done = self.run_axis_rows(img, pass_x);
        let t = rows_done.transpose();
        let cols_done = self.run_axis_rows(&t, pass_y);
        cols_done.transpose()
    }

    /// Gaussian-smoothed image: `G_y ⊛ (G_x ⊛ I)`.
    pub fn smooth(&self, img: &Image) -> Image {
        self.separable(img, Pass::Smooth, Pass::Smooth)
    }

    /// ∂/∂x of the smoothed image.
    pub fn dx(&self, img: &Image) -> Image {
        self.separable(img, Pass::D1, Pass::Smooth)
    }

    /// ∂/∂y of the smoothed image.
    pub fn dy(&self, img: &Image) -> Image {
        self.separable(img, Pass::Smooth, Pass::D1)
    }

    /// Gradient magnitude `√(Ix² + Iy²)` of the smoothed image.
    pub fn gradient_magnitude(&self, img: &Image) -> Image {
        let gx = self.dx(img);
        let gy = self.dy(img);
        let mut out = Image::zeros(img.width, img.height);
        for i in 0..out.data.len() {
            out.data[i] = (gx.data[i] * gx.data[i] + gy.data[i] * gy.data[i]).sqrt();
        }
        out
    }

    /// Laplacian of Gaussian: `Ixx + Iyy` (blob/scale-space detector).
    pub fn laplacian(&self, img: &Image) -> Image {
        let xx = self.separable(img, Pass::D2, Pass::Smooth);
        let yy = self.separable(img, Pass::Smooth, Pass::D2);
        let mut out = Image::zeros(img.width, img.height);
        for i in 0..out.data.len() {
            out.data[i] = xx.data[i] + yy.data[i];
        }
        out
    }

    /// O(KN) separable reference using the direct 1D convolutions
    /// (the image-domain GCT3 — used by the tests and benches).
    pub fn smooth_direct(&self, img: &Image) -> Image {
        let rows_done = self.run_rows_with(img, |row| self.smoother.smooth_direct(row));
        let t = rows_done.transpose();
        let cols = self.run_rows_with(&t, |row| self.smoother.smooth_direct(row));
        cols.transpose()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::Rng64;

    fn test_image(w: usize, h: usize, seed: u64) -> Image {
        let mut rng = Rng64::new(seed);
        // smooth blobs + noise: representative natural-image-ish content
        let mut img = Image::from_fn(w, h, |x, y| {
            let fx = x as f64 / w as f64;
            let fy = y as f64 / h as f64;
            (6.3 * fx).sin() * (4.2 * fy).cos() + 0.5 * (12.0 * fx * fy).sin()
        });
        for y in 0..h {
            for x in 0..w {
                let v = img.get(x, y) + 0.1 * rng.normal();
                img.set(x, y, v);
            }
        }
        img
    }

    #[test]
    fn image_roundtrip_accessors() {
        let mut img = Image::zeros(4, 3);
        img.set(2, 1, 7.5);
        assert_eq!(img.get(2, 1), 7.5);
        assert_eq!(img.row(1)[2], 7.5);
        let mut col = Vec::new();
        img.column(2, &mut col);
        assert_eq!(col, vec![0.0, 7.5, 0.0]);
    }

    #[test]
    fn from_vec_validates_length() {
        assert!(Image::from_vec(3, 2, vec![0.0; 6]).is_ok());
        assert!(Image::from_vec(3, 2, vec![0.0; 5]).is_err());
    }

    #[test]
    fn transpose_is_involution() {
        let img = test_image(17, 9, 3);
        assert_eq!(img.transpose().transpose(), img);
    }

    #[test]
    fn smooth_matches_direct_separable() {
        let img = test_image(96, 64, 1);
        let sm = ImageSmoother::new(4.0, 6).unwrap();
        let fast = sm.smooth(&img);
        let direct = sm.smooth_direct(&img);
        let e = fast.interior_rel_rmse(&direct, sm.k());
        assert!(e < 5e-3, "{e}");
    }

    #[test]
    fn smoothing_reduces_noise_variance() {
        let img = test_image(80, 80, 7);
        let sm = ImageSmoother::new(3.0, 5).unwrap();
        let out = sm.smooth(&img);
        // high-frequency energy must drop: compare pixel-difference energy
        let hf = |im: &Image| -> f64 {
            let mut acc = 0.0;
            for y in 0..im.height {
                for x in 1..im.width {
                    let d = im.get(x, y) - im.get(x - 1, y);
                    acc += d * d;
                }
            }
            acc
        };
        assert!(hf(&out) < 0.2 * hf(&img));
    }

    #[test]
    fn gradient_of_linear_ramp_is_constant() {
        // I(x, y) = 3x + 2y ⇒ Ix = 3, Iy = 2 (up to edge effects and the
        // G_D fit error, a few % at small K — paper Table 1 e(G_D) column)
        let img = Image::from_fn(96, 96, |x, y| 3.0 * x as f64 + 2.0 * y as f64);
        let sm = ImageSmoother::new(4.0, 6).unwrap();
        let gx = sm.dx(&img);
        let gy = sm.dy(&img);
        let m = 3 * sm.k();
        for y in m..96 - m {
            for x in m..96 - m {
                assert!((gx.get(x, y) - 3.0).abs() < 0.15, "gx {}", gx.get(x, y));
                assert!((gy.get(x, y) - 2.0).abs() < 0.10, "gy {}", gy.get(x, y));
            }
        }
    }

    #[test]
    fn gradient_magnitude_peaks_on_edge() {
        // vertical step edge at x = 32
        let img = Image::from_fn(64, 64, |x, _| if x < 32 { 0.0 } else { 1.0 });
        let sm = ImageSmoother::new(2.0, 6).unwrap();
        let g = sm.gradient_magnitude(&img);
        let mid = 32;
        for y in 20..44 {
            // edge response dominates the flat regions
            assert!(g.get(mid, y) > 5.0 * g.get(8, y) + 1e-9);
        }
    }

    #[test]
    fn laplacian_sign_flips_across_blob() {
        // bright Gaussian blob: LoG is negative at the centre,
        // positive in the surround ring
        let img = Image::from_fn(96, 96, |x, y| {
            let dx = x as f64 - 48.0;
            let dy = y as f64 - 48.0;
            (-(dx * dx + dy * dy) / (2.0 * 36.0)).exp()
        });
        let sm = ImageSmoother::new(3.0, 6).unwrap();
        let log = sm.laplacian(&img);
        assert!(log.get(48, 48) < 0.0);
        assert!(log.get(48 + 14, 48) > log.get(48, 48));
    }

    #[test]
    fn recursive_algorithm_agrees_with_kernel_integral() {
        let img = test_image(64, 48, 11);
        let a = ImageSmoother::new(3.5, 5)
            .unwrap()
            .with_algorithm(Algorithm::KernelIntegral)
            .smooth(&img);
        let b = ImageSmoother::new(3.5, 5)
            .unwrap()
            .with_algorithm(Algorithm::Recursive1)
            .smooth(&img);
        assert!(a.max_abs_diff(&b) < 1e-8);
    }

    #[test]
    fn constant_image_is_preserved() {
        let img = Image::from_fn(48, 48, |_, _| 2.5);
        let sm = ImageSmoother::new(4.0, 5).unwrap();
        let out = sm.smooth(&img);
        let m = 2 * sm.k();
        for y in m..48 - m {
            for x in m..48 - m {
                assert!((out.get(x, y) - 2.5).abs() < 0.02, "{}", out.get(x, y));
            }
        }
    }
}
