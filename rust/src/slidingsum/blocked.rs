//! Algorithms 2-3: the shared-memory radix-8 blocked sliding sum.
//!
//! One GPU "stage" (the paper's `SSSG` subprogram) consumes three bits of the
//! window length: each block loads a 16-lane tile of the current-stride
//! layout into shared memory (`s`, `t`), performs the three gated doubling
//! steps in shared memory, and writes the first 8 lanes back (the paper's
//! Fig. 2 rearrangement is a coalescing transpose; we keep the arrays in
//! original order and do the stride arithmetic directly, which is
//! value-equivalent, and charge its traffic to the counters).
//!
//! The 16-lane overlap is what makes the schedule valid: an output lane
//! j ≤ 7 reaches at most lane j + 1 + 2 + 4 = j + 7 ≤ 14 during the three
//! steps, so every neighbour it needs is resident in the tile.

use super::bit;
use crate::dsp::Float;

/// Execution counters for the blocked schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct BlockedStats {
    /// Number of SSSG stages (= ⌈bits(L)/3⌉).
    pub stages: usize,
    /// Parallel depth: 3 doubling steps + load + store per stage.
    pub depth: usize,
    /// Shared-memory accesses (reads+writes inside tiles).
    pub shared_accesses: u64,
    /// Global-memory accesses (tile loads + result stores).
    pub global_accesses: u64,
    /// Scalar additions.
    pub additions: u64,
}

/// Blocked sliding sum: `h[n] = Σ_{k=0}^{L-1} f[n+k]`, zero-extended.
/// Generic over [`Float`] (see [`super::sliding_sum_naive`]'s note on the
/// f32 instantiation).
pub fn sliding_sum_blocked<T: Float>(f: &[T], l: usize) -> (Vec<T>, BlockedStats) {
    let n = f.len();
    let mut stats = BlockedStats::default();
    if l == 0 || n == 0 {
        return (vec![T::ZERO; n], stats);
    }
    let mut g = f.to_vec();
    let mut h = vec![T::ZERO; n];
    let mut rem = l;
    let mut stride = 1usize;

    while rem > 0 {
        let bits = [bit(rem, 0), bit(rem, 1), bit(rem, 2)];
        stats.stages += 1;
        stats.depth += 3 + 2; // 3 doubling steps + tile load + tile store

        // Tiles: outputs are the 8 lanes {o, o+stride, .., o+7·stride};
        // tile origins o enumerate every output index exactly once.
        let tile_span = 8 * stride;
        let mut g_next = g.clone();
        let mut h_next = h.clone();
        let mut q = 0usize;
        while q * tile_span < n {
            for b in 0..stride.min(n - q * tile_span) {
                let o = q * tile_span + b;
                // shared-memory tile load (Alg. 3 header)
                let mut s = [T::ZERO; 16];
                let mut t = [T::ZERO; 16];
                for (j, (sj, tj)) in s.iter_mut().zip(t.iter_mut()).enumerate() {
                    let idx = o + j * stride;
                    if idx < n {
                        *sj = g[idx];
                        *tj = h[idx];
                    }
                }
                stats.global_accesses += 32;

                // three gated doubling steps in shared memory
                for (r, &b_set) in bits.iter().enumerate() {
                    let step = 1usize << r;
                    for j in 0..16 - step {
                        if b_set {
                            t[j] = s[j] + t[j + step];
                            stats.shared_accesses += 3;
                            stats.additions += 1;
                        }
                        s[j] += s[j + step];
                        stats.shared_accesses += 3;
                        stats.additions += 1;
                    }
                }

                // write back the 8 output lanes
                for j in 0..8 {
                    let idx = o + j * stride;
                    if idx < n {
                        g_next[idx] = s[j];
                        h_next[idx] = t[j];
                    }
                }
                stats.global_accesses += 16;
            }
            q += 1;
        }
        g = g_next;
        h = h_next;
        rem >>= 3;
        stride *= 8;
    }
    (h, stats)
}

#[cfg(test)]
mod tests {
    use super::super::{sliding_sum_doubling, sliding_sum_naive};
    use super::*;
    use crate::dsp::gaussian_noise;

    #[test]
    fn matches_naive_for_many_lengths() {
        let f = gaussian_noise(300, 1.0, 50);
        for l in [1usize, 2, 7, 8, 9, 63, 64, 65, 100, 255, 299] {
            let (h, _) = sliding_sum_blocked(&f, l);
            let want = sliding_sum_naive(&f, l);
            for i in 0..f.len() {
                assert!((h[i] - want[i]).abs() < 1e-9, "l={l} i={i}");
            }
        }
    }

    #[test]
    fn matches_doubling_exactly() {
        // Same binary decomposition, same addition tree shapes up to
        // reassociation — values agree to f64 roundoff.
        let f = gaussian_noise(200, 1.0, 51);
        for l in [5usize, 40, 129] {
            let (a, _) = sliding_sum_blocked(&f, l);
            let (b, _) = sliding_sum_doubling(&f, l);
            for i in 0..f.len() {
                assert!((a[i] - b[i]).abs() < 1e-10, "l={l} i={i}");
            }
        }
    }

    #[test]
    fn stage_count_is_ceil_bits_over_3() {
        let f = gaussian_noise(64, 1.0, 52);
        for (l, want) in [(1usize, 1usize), (7, 1), (8, 2), (63, 2), (64, 3), (511, 3), (512, 4)] {
            let (_, stats) = sliding_sum_blocked(&f, l);
            assert_eq!(stats.stages, want, "l={l}");
        }
    }

    #[test]
    fn shared_traffic_dominates_global() {
        // the whole point of Alg. 2-3: most accesses hit shared memory
        let f = gaussian_noise(4096, 1.0, 53);
        let (_, stats) = sliding_sum_blocked(&f, 1000);
        assert!(stats.shared_accesses > stats.global_accesses);
    }

    #[test]
    fn depth_independent_of_n() {
        let (_, s1) = sliding_sum_blocked(&gaussian_noise(100, 1.0, 1), 77);
        let (_, s2) = sliding_sum_blocked(&gaussian_noise(10_000, 1.0, 2), 77);
        assert_eq!(s1.depth, s2.depth);
    }
}
