//! The paper's GPU sliding-sum algorithms (§4) as machine-checkable Rust:
//! Algorithm 1 (log-depth doubling over global memory) and Algorithms 2-3
//! (the shared-memory radix-8 blocked schedule), with parallel-step and
//! memory-traffic accounting used by [`crate::gpu_model`].
//!
//! These are *executions* of the parallel schedules on the CPU — every array
//! update in one `r`-step is data-independent exactly as on the GPU, so the
//! results are bit-equivalent to the parallel version, and the depth/access
//! counters are exact.

mod blocked;

pub use blocked::{sliding_sum_blocked, BlockedStats};

use crate::dsp::Float;

/// `h[n] = Σ_{k=0}^{L-1} f[n+k]` by definition (eq. 62) — O(NL) oracle.
///
/// Generic over [`Float`]: the f32 instantiation is the summation the f32
/// execution tier ([`crate::plan::Precision::F32`]) models on the GPU path,
/// and the one the [`crate::precision`] drift study measures.
pub fn sliding_sum_naive<T: Float>(f: &[T], l: usize) -> Vec<T> {
    let n = f.len();
    (0..n)
        .map(|i| f[i..(i + l).min(n)].iter().copied().sum())
        .collect()
}

/// B(m, r): bit r of m (eq. 63).
#[inline]
pub fn bit(m: usize, r: usize) -> bool {
    (m >> r) & 1 == 1
}

/// Execution statistics of one parallel sliding-sum run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepStats {
    /// Parallel depth: number of sequential array-wide steps.
    pub depth: usize,
    /// Total scalar additions across all lanes.
    pub additions: u64,
    /// Total global-memory reads + writes (each lane-step touches ≤ 3 cells).
    pub global_accesses: u64,
}

/// Algorithm 1: log-depth doubling sliding sum.
///
/// ```text
/// g_{r+1}[n] = g_r[n] + g_r[n + 2^r]
/// h_{r+1}[n] = g_r[n] + h_r[n + 2^r]   if B(L, r) = 1, else h_r[n]
/// ```
///
/// Returns `(h, stats)`; `h[n] = Σ_{k=0}^{L-1} f[n+k]` with zero beyond the
/// end. Depth is `R = ⌈log₂(L+1)⌉` — independent of N, the paper's claim:
///
/// ```
/// use masft::slidingsum::{doubling_depth, sliding_sum_doubling};
///
/// let short = vec![1.0; 100];
/// let long = vec![1.0; 100_000];
/// let (h, stats_short) = sliding_sum_doubling(&short, 64);
/// let (_, stats_long) = sliding_sum_doubling(&long, 64);
/// assert_eq!(h[0], 64.0); // the window sum itself
/// // parallel depth is independent of the signal length N ...
/// assert_eq!(stats_short.depth, stats_long.depth);
/// assert_eq!(stats_short.depth, doubling_depth(64)); // 7 g-steps + 1 h-merge
/// // ... and grows only logarithmically in the window length L
/// assert!(doubling_depth(1 << 20) <= 2 * 21);
/// ```
pub fn sliding_sum_doubling<T: Float>(f: &[T], l: usize) -> (Vec<T>, StepStats) {
    let n = f.len();
    let mut stats = StepStats::default();
    if l == 0 || n == 0 {
        return (vec![T::ZERO; n], stats);
    }
    let mut r_max = 0;
    while (1usize << r_max) <= l {
        r_max += 1;
    }
    let mut g = f.to_vec();
    let mut h = vec![T::ZERO; n];
    for r in 0..r_max {
        let step = 1usize << r;
        if bit(l, r) {
            // h[n] <- g[n] + h[n + 2^r]  (whole-row, data-independent)
            for i in 0..n {
                let hn = if i + step < n { h[i + step] } else { T::ZERO };
                h[i] = g[i] + hn;
            }
            stats.depth += 1;
            stats.additions += n as u64;
            stats.global_accesses += 3 * n as u64;
        }
        // g[n] <- g[n] + g[n + 2^r]
        for i in 0..n {
            let gn = if i + step < n { g[i + step] } else { T::ZERO };
            g[i] += gn;
        }
        stats.depth += 1;
        stats.additions += n as u64;
        stats.global_accesses += 3 * n as u64;
    }
    (h, stats)
}

/// Depth of Algorithm 1 for window length `l` (number of parallel steps),
/// without running it: the g-doubling steps plus one h-merge per set bit.
pub fn doubling_depth(l: usize) -> usize {
    if l == 0 {
        return 0;
    }
    let r_max = usize::BITS as usize - l.leading_zeros() as usize;
    r_max + l.count_ones() as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::gaussian_noise;

    #[test]
    fn matches_naive_for_many_lengths() {
        let f = gaussian_noise(257, 1.0, 40);
        for l in [1usize, 2, 3, 5, 8, 13, 31, 32, 33, 100, 255, 256, 257] {
            let (h, _) = sliding_sum_doubling(&f, l);
            let want = sliding_sum_naive(&f, l);
            for i in 0..f.len() {
                assert!((h[i] - want[i]).abs() < 1e-9, "l={l} i={i}");
            }
        }
    }

    #[test]
    fn f32_instantiation_matches_naive() {
        // the generic core at f32 — the summation the f32 tier executes
        let f64s = gaussian_noise(257, 1.0, 41);
        let f: Vec<f32> = f64s.iter().map(|&v| v as f32).collect();
        for l in [1usize, 3, 32, 100, 257] {
            let (h, stats) = sliding_sum_doubling(&f, l);
            let want = sliding_sum_naive(&f, l);
            for i in 0..f.len() {
                assert!((h[i] - want[i]).abs() < 1e-3, "l={l} i={i}");
            }
            assert_eq!(stats.depth, doubling_depth(l));
        }
    }

    #[test]
    fn zero_length_window() {
        let f = gaussian_noise(16, 1.0, 1);
        let (h, stats) = sliding_sum_doubling(&f, 0);
        assert!(h.iter().all(|&v| v == 0.0));
        assert_eq!(stats.depth, 0);
    }

    #[test]
    fn length_one_is_identity() {
        let f = gaussian_noise(64, 1.0, 2);
        let (h, _) = sliding_sum_doubling(&f, 1);
        assert_eq!(h, f);
    }

    #[test]
    fn depth_is_logarithmic_and_n_independent() {
        let f_small = gaussian_noise(100, 1.0, 3);
        let f_large = gaussian_noise(10_000, 1.0, 3);
        let (_, s_small) = sliding_sum_doubling(&f_small, 64);
        let (_, s_large) = sliding_sum_doubling(&f_large, 64);
        assert_eq!(s_small.depth, s_large.depth); // depth independent of N
        assert_eq!(s_small.depth, doubling_depth(64));
        // log growth in L:
        assert!(doubling_depth(8192) <= doubling_depth(8191) + 2);
        assert!(doubling_depth(1 << 20) < 2 * 21);
    }

    #[test]
    fn depth_formula_matches_execution() {
        let f = gaussian_noise(300, 1.0, 9);
        for l in [1usize, 7, 33, 100, 255] {
            let (_, stats) = sliding_sum_doubling(&f, l);
            assert_eq!(stats.depth, doubling_depth(l), "l={l}");
        }
    }

    #[test]
    fn bit_extraction() {
        assert!(bit(5, 0) && !bit(5, 1) && bit(5, 2) && !bit(5, 3));
    }

    #[test]
    fn window_spilling_past_end_is_zero_extended() {
        let f = vec![1.0; 10];
        let (h, _) = sliding_sum_doubling(&f, 4);
        assert_eq!(h[9], 1.0);
        assert_eq!(h[7], 3.0);
        assert_eq!(h[0], 4.0);
    }

    #[test]
    fn empty_input_all_variants() {
        let empty: Vec<f64> = Vec::new();
        assert!(sliding_sum_naive(&empty, 5).is_empty());
        let (h, stats) = sliding_sum_doubling(&empty, 5);
        assert!(h.is_empty());
        assert_eq!(stats, StepStats::default());
        let (hb, bstats) = sliding_sum_blocked(&empty, 5);
        assert!(hb.is_empty());
        assert_eq!(bstats, BlockedStats::default());
    }

    #[test]
    fn degenerate_windows_agree_across_variants() {
        // l == 0 (empty window) and l == 1 (identity) are exact for all
        // three implementations — no rounding enters either case.
        let f = gaussian_noise(33, 1.0, 78);
        for l in [0usize, 1] {
            let naive = sliding_sum_naive(&f, l);
            let (hd, _) = sliding_sum_doubling(&f, l);
            let (hb, _) = sliding_sum_blocked(&f, l);
            assert_eq!(hd, naive, "doubling l={l}");
            assert_eq!(hb, naive, "blocked l={l}");
        }
    }

    #[test]
    fn window_longer_than_signal_agrees_across_variants() {
        // l > n: every output is a tail sum Σ_{j>=i} f[j] (zero extension)
        let f = gaussian_noise(10, 1.0, 77);
        for l in [11usize, 16, 100] {
            let naive = sliding_sum_naive(&f, l);
            let (hd, _) = sliding_sum_doubling(&f, l);
            let (hb, _) = sliding_sum_blocked(&f, l);
            for i in 0..f.len() {
                assert!((hd[i] - naive[i]).abs() < 1e-12, "doubling l={l} i={i}");
                assert!((hb[i] - naive[i]).abs() < 1e-12, "blocked l={l} i={i}");
            }
            // the full-tail value at the head is the total sum
            let total: f64 = f.iter().sum();
            assert!((hd[0] - total).abs() < 1e-12, "l={l}");
        }
    }
}
