//! Online (streaming) SFT/ASFT: block-oriented, bounded-state evaluation —
//! the real-time counterpart of the batch paths in [`crate::plan`].
//!
//! The paper's recursive formulations (eqs. 21, 28, 37) are inherently
//! streaming: each output needs only the filter state plus a 2K-sample
//! delay line. This module packages them as a first-class subsystem (the
//! streaming formulation is derived in [DESIGN.md §6](crate::design)):
//!
//! * [`StreamingGaussian`] / [`StreamingMorlet`] — fused weighted-bank
//!   processors sharing the *exact* recurrence, warm-up, and MMSE weights of
//!   the batch plans, so their output is **bit-identical** to
//!   [`crate::plan::GaussianPlan`] / [`crate::plan::MorletPlan`] with zero
//!   extension — sample-at-a-time ([`StreamingGaussian::push`]) or
//!   block-at-a-time ([`StreamingGaussian::push_block_into`]), scalar or
//!   SIMD lanes ([`Backend`]). Proven in `rust/tests/streaming_parity.rs`.
//! * [`StreamingScalogram`] — a multi-scale Morlet bank sharing one delay
//!   line, scale rows fanned across [`crate::exec::Parallelism`] workers.
//! * [`StreamingPlan`] — the plan-integration front-end:
//!   [`crate::plan::TransformSpec::stream`] turns the same validated specs
//!   (and the same process-wide fit cache) the batch plans use into a
//!   streaming processor, so batch and streaming stay one API.
//! * [`StreamingSft`] / [`StreamingAsft`] — single-component processors via
//!   the paper's own recursive forms (eq. 21 and eq. 37), kept as the
//!   per-component reference and for the f32-oriented attenuated variant
//!   (see [DESIGN.md §6.4](crate::design) for why ASFT is the form that
//!   survives f32 streams).
//!
//! # Latency and lifecycle
//!
//! Every processor has a fixed latency of K samples ([DESIGN.md
//! §6.1](crate::design)): the output at signal index `n` becomes available
//! once sample `n + K` has been pushed. `finish*` flushes the last K outputs
//! by pushing K zeros — exactly the batch zero extension ([DESIGN.md
//! §6.2](crate::design)) — and leaves the processor *spent*; call
//! [`StreamingGaussian::reset`] (available on every streaming type) to
//! rewind it to a fresh stream without reallocating state, which is how the
//! coordinator's session layer ([`crate::coordinator::StreamSession`])
//! reuses per-client processors.

mod bank;
mod component;
mod front;
mod processors;
mod scalogram;

pub use component::{StreamingAsft, StreamingSft};
pub use front::{BlockOut, StreamingPlan};
pub use processors::{StreamingGaussian, StreamingMorlet};
pub use scalogram::StreamingScalogram;

pub(crate) use bank::{BankCore, History};
pub(crate) use processors::morlet_bank;

use crate::Result;

/// Lane-execution backend of the streaming bank processors
/// ([`StreamingGaussian`], [`StreamingMorlet`], [`StreamingScalogram`]).
///
/// Both backends run the same per-lane expression tree in the same order, so
/// output is **bit-identical** across the knob (the same contract as
/// [`crate::plan::Backend::Simd`] vs [`crate::plan::Backend::PureRust`] on
/// the batch plans — see [`crate::simd`]'s bit-identity notes). The knob
/// composes with the spec's [`crate::plan::Precision`]: an f32 spec streams
/// through the f32 instantiation of the same bank core
/// (`rust/tests/precision_parity.rs` pins scalar ↔ SIMD ↔ streaming-block
/// equality at f32).
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum Backend {
    /// Scalar lane loop — the reference path.
    #[default]
    Scalar,
    /// [`crate::simd::F64x4`] lane blocks — bit-identical to scalar.
    Simd,
}

/// Map a plan backend onto a streaming lane backend.
/// [`crate::plan::Backend::Runtime`] has no streaming form (the runtime
/// executes whole fixed-size buckets) and is rejected.
pub(crate) fn stream_backend(b: crate::plan::Backend) -> Result<Backend> {
    match b {
        crate::plan::Backend::PureRust => Ok(Backend::Scalar),
        crate::plan::Backend::Simd => Ok(Backend::Simd),
        crate::plan::Backend::Runtime => anyhow::bail!(
            "the runtime backend executes fixed-size batch buckets and cannot stream; \
             use Backend::PureRust or Backend::Simd"
        ),
        // Processor constructors resolve Auto before mapping (crate::tune);
        // this arm is the defensive backstop for hand-assembled specs.
        crate::plan::Backend::Auto => anyhow::bail!(
            "Backend::Auto must be resolved before streaming; build the \
             processor through from_spec/stream()"
        ),
    }
}
