//! Online (streaming) SFT/ASFT: sample-at-a-time evaluation with bounded
//! state — the real-time counterpart of the batch paths in [`crate::sft`].
//!
//! The paper's recursive formulations (eqs. 21, 28, 37) are inherently
//! streaming: each output needs only the filter state plus a 2K-sample
//! delay line. This module packages them behind push-style processors:
//!
//! * [`StreamingSft`] — one (β, p) component via the kernel-integral
//!   recurrence (eq. 21), f64 state.
//! * [`StreamingAsft`] — the attenuated variant (eq. 37), safe for long
//!   runs in f32 (the whole point of ASFT, §2.4).
//! * [`StreamingGaussian`] / [`StreamingMorlet`] — P-component banks with
//!   the MMSE weights, producing smoothed samples / wavelet coefficients
//!   with a fixed latency of K samples.
//!
//! Outputs match the batch implementations exactly in the interior (tests
//! below) — the stream prepends K zeros of warm-up, mirroring the batch
//! zero extension.

use crate::dsp::Complex;
use crate::morlet::Method;
use crate::plan::cache as fit_cache;
use crate::plan::{GaussianSpec, MorletSpec};
use crate::Result;

/// Ring-buffer delay line of fixed length `d`: `push` returns the sample
/// that entered `d` pushes ago (zero-initialized).
#[derive(Clone, Debug)]
struct DelayLine {
    buf: Vec<f64>,
    idx: usize,
}

impl DelayLine {
    fn new(d: usize) -> Self {
        Self {
            buf: vec![0.0; d.max(1)],
            idx: 0,
        }
    }

    #[inline]
    fn push(&mut self, v: f64) -> f64 {
        let out = self.buf[self.idx];
        self.buf[self.idx] = v;
        self.idx += 1;
        if self.idx == self.buf.len() {
            self.idx = 0;
        }
        out
    }
}

/// One streaming SFT component c_p − i·s_p at (β, p), kernel-integral
/// recurrence (eq. 21): `u₂ₖ₊₁[n] = u₂ₖ₊₁[n−1] + x[n]e^{iβpn} − x[n−2K−1]e^{iβp(n−2K−1)}`.
///
/// Latency: the component at signal index `n − K` becomes available after
/// pushing sample `n` (the window `[n−2K, n]` is centred at `n − K`).
#[derive(Clone, Debug)]
pub struct StreamingSft {
    k: usize,
    /// e^{iβp}
    rot: Complex<f64>,
    /// e^{iβp·n} running modulator
    mod_new: Complex<f64>,
    /// e^{iβp·(n−2K−1)} running modulator for the leaving sample
    mod_old: Complex<f64>,
    /// windowed kernel integral u_{(2K+1)}
    u: Complex<f64>,
    /// e^{-iβp·(n−K)} demodulator for the output point
    demod: Complex<f64>,
    delay: DelayLine,
    pushed: usize,
    /// renormalization counter (long-run phase drift control)
    renorm: usize,
}

impl StreamingSft {
    /// One component processor at window half-width `k`, frequency `beta·p`.
    pub fn new(k: usize, beta: f64, p: f64) -> Result<Self> {
        anyhow::ensure!(k >= 1, "K must be >= 1");
        let th = beta * p;
        Ok(Self {
            k,
            rot: Complex::cis(th),
            mod_new: Complex::one(),
            // first leaving sample has index −(2K+1): e^{iβp·(−2K−1)}
            mod_old: Complex::cis(-th * (2 * k + 1) as f64),
            u: Complex::zero(),
            // first output is at signal index 0 ⇒ demod starts at e^{0} = 1
            demod: Complex::one(),
            delay: DelayLine::new(2 * k + 1),
            pushed: 0,
            renorm: 0,
        })
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; returns `(c, s)` for signal index `pushed − 1 − K`
    /// once enough samples have arrived (`None` during the first K pushes).
    pub fn push(&mut self, x: f64) -> Option<(f64, f64)> {
        let x_old = self.delay.push(x);
        self.u += self.mod_new.scale(x) - self.mod_old.scale(x_old);
        self.mod_new = self.mod_new * self.rot;
        self.mod_old = self.mod_old * self.rot;
        self.pushed += 1;

        // unit-circle renormalization every 4096 steps: the rotators are
        // products of cis() values, so their modulus drifts at ~ε per step
        self.renorm += 1;
        if self.renorm == 4096 {
            self.renorm = 0;
            for m in [&mut self.mod_new, &mut self.mod_old, &mut self.demod] {
                let n = m.norm();
                if n > 0.0 {
                    *m = m.scale(1.0 / n);
                }
            }
        }

        if self.pushed <= self.k {
            return None;
        }
        // eq. 20: c − i·s = e^{-iβp(n−K)}·u at window centre n−K
        let v = self.demod * self.u;
        self.demod = self.demod * self.rot.conj();
        Some((v.re, -v.im))
    }

    /// Flush the tail: push K zeros so the final K outputs emerge.
    pub fn finish(&mut self) -> Vec<(f64, f64)> {
        (0..self.k).filter_map(|_| self.push(0.0)).collect()
    }
}

/// Streaming ASFT component (eq. 37):
/// `ṽ₂ₖ[n] = e^{−α−iβp}·ṽ₂ₖ[n−1] + x[n] − e^{−2αK}x[n−2K]`,
/// recombined as in [`crate::sft::asft::components_r1`] (the crate's
/// `e^{−αk}`-weight convention: `c̃ − i·s̃ = (−1)^p e^{+αK}(ṽ₂ₖ[m+K] +
/// e^{−2αK}x[m−K])`). Bounded state for α > 0 — this is the variant meant
/// for indefinite runs on f32 hardware.
#[derive(Clone, Debug)]
pub struct StreamingAsft {
    k: usize,
    p: usize,
    alpha: f64,
    /// e^{−α−iβp}
    decay_rot: Complex<f64>,
    /// e^{−2αK}
    edge: f64,
    v: Complex<f64>,
    delay_2k: DelayLine,
    pushed: usize,
}

impl StreamingAsft {
    /// One attenuated component processor at (K, p, α).
    pub fn new(k: usize, p: usize, alpha: f64) -> Result<Self> {
        anyhow::ensure!(k >= 1, "K must be >= 1");
        anyhow::ensure!(alpha >= 0.0, "alpha must be >= 0");
        let beta = std::f64::consts::PI / k as f64;
        Ok(Self {
            k,
            p,
            alpha,
            decay_rot: Complex::cis(-(beta * p as f64)).scale((-alpha).exp()),
            edge: (-2.0 * alpha * k as f64).exp(),
            v: Complex::zero(),
            delay_2k: DelayLine::new(2 * k),
            pushed: 0,
        })
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; yields `(c̃, s̃)` at index `pushed − 1 − K`.
    pub fn push(&mut self, x: f64) -> Option<(f64, f64)> {
        // x[t−2K] serves both the truncated recurrence and, at output time
        // (window centre m = t−K), the x[m−K] recombination term.
        let x_2k = self.delay_2k.push(x);
        self.v = self.decay_rot * self.v + Complex::new(x - self.edge * x_2k, 0.0);
        self.pushed += 1;
        if self.pushed <= self.k {
            return None;
        }
        let sign = if self.p % 2 == 0 { 1.0 } else { -1.0 };
        let w = sign * (self.alpha * self.k as f64).exp();
        let val = (self.v + Complex::new(self.edge * x_2k, 0.0)).scale(w);
        Some((val.re, -val.im))
    }

    /// Flush the tail: push K zeros so the final K outputs emerge.
    pub fn finish(&mut self) -> Vec<(f64, f64)> {
        (0..self.k).filter_map(|_| self.push(0.0)).collect()
    }
}

/// Streaming Gaussian smoother: a bank of [`StreamingSft`] components with
/// the MMSE weights of [`crate::gaussian::GaussianSmoother`]. Emits the
/// smoothed sample at latency K.
#[derive(Clone, Debug)]
pub struct StreamingGaussian {
    bank: Vec<StreamingSft>,
    a: Vec<f64>,
    /// Window half-width K (= the output latency).
    pub k: usize,
}

impl StreamingGaussian {
    /// Streaming smoother at (σ, P), K = ⌈3σ⌉.
    pub fn new(sigma: f64, p: usize) -> Result<Self> {
        // Validation and the MMSE fit are shared with the batch paths: the
        // plan spec builder checks the parameters, the process-wide cache
        // fits each configuration once.
        let spec = GaussianSpec::builder(sigma).order(p).build()?;
        let fit = fit_cache::gaussian_fit(spec.sigma, spec.k, spec.p, spec.beta);
        let bank = (0..=p)
            .map(|j| StreamingSft::new(spec.k, spec.beta, j as f64))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            bank,
            a: fit.a.clone(),
            k: spec.k,
        })
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; returns the smoothed value at index `pushed−1−K`.
    pub fn push(&mut self, x: f64) -> Option<f64> {
        let mut acc = 0.0;
        let mut ready = false;
        for (sft, &a) in self.bank.iter_mut().zip(&self.a) {
            if let Some((c, _)) = sft.push(x) {
                acc += a * c;
                ready = true;
            }
        }
        ready.then_some(acc)
    }

    /// Flush the last K outputs (zero extension).
    pub fn finish(&mut self) -> Vec<f64> {
        (0..self.k).filter_map(|_| self.push(0.0)).collect()
    }
}

/// Streaming Morlet transform (direct method, eq. 54) with latency K.
#[derive(Clone, Debug)]
pub struct StreamingMorlet {
    bank: Vec<StreamingSft>,
    m: Vec<f64>,
    l: Vec<f64>,
    /// Window half-width K (= the output latency).
    pub k: usize,
}

impl StreamingMorlet {
    /// Streaming direct-method transform at (σ, ξ, P_D), K = ⌈3σ⌉.
    pub fn new(sigma: f64, xi: f64, p_d: usize) -> Result<Self> {
        // Same single home for validation and fits as the batch paths.
        let spec = MorletSpec::builder(sigma, xi)
            .method(Method::DirectSft { p_d })
            .build()?;
        let (k, beta) = (spec.k, spec.beta());
        let p_s = fit_cache::optimal_ps(sigma, xi, k, p_d, beta);
        let fit = fit_cache::morlet_direct_fit(sigma, xi, k, p_s, p_d, beta);
        let bank = (0..p_d)
            .map(|j| StreamingSft::new(k, beta, (p_s + j) as f64))
            .collect::<Result<Vec<_>>>()?;
        Ok(Self {
            bank,
            m: fit.m.clone(),
            l: fit.l.clone(),
            k,
        })
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; returns the wavelet coefficient at `pushed−1−K`.
    pub fn push(&mut self, x: f64) -> Option<Complex<f64>> {
        let mut acc = Complex::zero();
        let mut ready = false;
        for (i, sft) in self.bank.iter_mut().enumerate() {
            if let Some((c, s)) = sft.push(x) {
                acc += Complex::new(self.m[i] * c, self.l[i] * s);
                ready = true;
            }
        }
        ready.then_some(acc)
    }

    /// Flush the last K coefficients (zero extension).
    pub fn finish(&mut self) -> Vec<Complex<f64>> {
        (0..self.k).filter_map(|_| self.push(0.0)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::{Rng64, SignalBuilder};
    use crate::gaussian::GaussianSmoother;
    use crate::morlet::{Method, MorletTransform};
    use crate::sft::{self, Algorithm};

    fn stream_all_sft(s: &mut StreamingSft, x: &[f64]) -> Vec<(f64, f64)> {
        let mut out: Vec<(f64, f64)> = x.iter().filter_map(|&v| s.push(v)).collect();
        out.extend(s.finish());
        out
    }

    #[test]
    fn streaming_sft_matches_batch() {
        let mut rng = Rng64::new(42);
        for &(k, p) in &[(8usize, 0usize), (12, 3), (20, 7), (16, 16)] {
            let n = 160;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let beta = std::f64::consts::PI / k as f64;
            let want = sft::components(Algorithm::Direct, &x, k, beta, p as f64);
            let mut s = StreamingSft::new(k, beta, p as f64).unwrap();
            let got = stream_all_sft(&mut s, &x);
            assert_eq!(got.len(), n);
            for i in 0..n {
                assert!(
                    (got[i].0 - want.c[i]).abs() < 1e-9,
                    "c k={k} p={p} i={i}: {} vs {}",
                    got[i].0,
                    want.c[i]
                );
                assert!(
                    (got[i].1 - want.s[i]).abs() < 1e-9,
                    "s k={k} p={p} i={i}"
                );
            }
        }
    }

    #[test]
    fn streaming_asft_matches_batch() {
        let mut rng = Rng64::new(7);
        for &(k, p, alpha) in &[(8usize, 2usize, 0.01), (16, 5, 0.004), (10, 0, 0.0)] {
            let n = 140;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let beta = std::f64::consts::PI / k as f64;
            let want = sft::direct::asft_components(&x, k, beta, p as f64, alpha);
            let mut s = StreamingAsft::new(k, p, alpha).unwrap();
            let mut got: Vec<(f64, f64)> = x.iter().filter_map(|&v| s.push(v)).collect();
            got.extend(s.finish());
            assert_eq!(got.len(), n);
            for i in 0..n {
                assert!(
                    (got[i].0 - want.c[i]).abs() < 1e-8,
                    "c k={k} p={p} i={i}: {} vs {}",
                    got[i].0,
                    want.c[i]
                );
                assert!((got[i].1 - want.s[i]).abs() < 1e-8, "s k={k} p={p} i={i}");
            }
        }
    }

    #[test]
    fn streaming_gaussian_matches_batch() {
        let x = SignalBuilder::new(400)
            .sine(0.01, 1.0, 0.2)
            .noise(0.4)
            .build();
        let (sigma, p) = (9.0, 6);
        let sm = GaussianSmoother::new(sigma, p).unwrap();
        let want = sm.smooth_sft(&x);
        let mut s = StreamingGaussian::new(sigma, p).unwrap();
        let mut got: Vec<f64> = x.iter().filter_map(|&v| s.push(v)).collect();
        got.extend(s.finish());
        assert_eq!(got.len(), x.len());
        for i in 0..x.len() {
            assert!((got[i] - want[i]).abs() < 1e-9, "i={i}");
        }
    }

    #[test]
    fn streaming_morlet_matches_batch() {
        let x = SignalBuilder::new(360)
            .chirp(0.004, 0.09, 1.0)
            .noise(0.2)
            .build();
        let (sigma, xi, p_d) = (12.0, 6.0, 6);
        let mt = MorletTransform::new(sigma, xi, Method::DirectSft { p_d }).unwrap();
        let want = mt.transform(&x);
        let mut s = StreamingMorlet::new(sigma, xi, p_d).unwrap();
        let mut got: Vec<Complex<f64>> = x.iter().filter_map(|&v| s.push(v)).collect();
        got.extend(s.finish());
        assert_eq!(got.len(), x.len());
        for i in 0..x.len() {
            assert!(
                (got[i] - want[i]).norm() < 1e-9,
                "i={i}: {:?} vs {:?}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn latency_is_k() {
        let mut s = StreamingGaussian::new(5.0, 4).unwrap();
        let k = s.latency();
        for i in 0..k {
            assert!(s.push(1.0).is_none(), "output before latency at {i}");
        }
        assert!(s.push(1.0).is_some());
    }

    #[test]
    fn long_run_phase_stability() {
        // 1M samples: the renormalized rotators must not drift. Compare a
        // late window against a fresh batch computation of the same window.
        let k = 16;
        let beta = std::f64::consts::PI / k as f64;
        let p = 3.0;
        let n = 1_000_000usize;
        let mut rng = Rng64::new(99);
        let mut s = StreamingSft::new(k, beta, p).unwrap();
        let mut window = std::collections::VecDeque::with_capacity(4 * k + 1);
        let mut last = (0.0, 0.0);
        let mut x_hist: Vec<f64> = Vec::with_capacity(4 * k + 1);
        for i in 0..n {
            let v = rng.normal();
            window.push_back(v);
            if window.len() > 4 * k + 1 {
                window.pop_front();
            }
            if let Some(out) = s.push(v) {
                last = out;
            }
            if i == n - 1 {
                x_hist = window.iter().copied().collect();
            }
        }
        // batch recompute: centre of the last full window is index −1−K
        // relative to the end of the stream; with hist length 4K+1 the
        // output index maps to hist position (4K+1) − 1 − K = 3K
        let m = x_hist.len();
        let centre = m - 1 - k;
        let mut want_c = 0.0;
        let mut want_s = 0.0;
        for (j, &v) in x_hist.iter().enumerate() {
            let kk = centre as f64 - j as f64; // x[n−k] convention
            if kk.abs() <= k as f64 {
                want_c += v * (beta * p * kk).cos();
                want_s += v * (beta * p * kk).sin();
            }
        }
        assert!(
            (last.0 - want_c).abs() < 1e-6,
            "c drift after 1M samples: {} vs {}",
            last.0,
            want_c
        );
        assert!((last.1 - want_s).abs() < 1e-6, "s drift after 1M samples");
    }
}
