//! Bank-based streaming processors: the batch Gaussian/Morlet hot paths as
//! push-style, bounded-state transforms.
//!
//! Both processors are thin wrappers over [`BankCore`] — the streaming form
//! of the fused weighted SFT bank — plus the same plane-selection /
//! carrier-weight epilogues the batch plans apply. They are built from the
//! *same validated specs* and resolve their MMSE weights through the *same
//! process-wide fit cache* as [`crate::plan::GaussianPlan`] /
//! [`crate::plan::MorletPlan`], and their outputs are **bit-identical** to
//! those plans under zero extension ([DESIGN.md §6.2](crate::design);
//! `rust/tests/streaming_parity.rs`).

use super::{stream_backend, BankCore, History};
use crate::dsp::{Complex, Extension};
use crate::morlet::Method;
use crate::plan::cache as fit_cache;
use crate::plan::{Derivative, GaussianSpec, MorletSpec};
use crate::Result;

/// Streaming Gaussian smoother / differential: the full (σ, P) MMSE bank
/// with latency K, block- or sample-at-a-time, scalar or SIMD lanes.
#[derive(Clone, Debug)]
pub struct StreamingGaussian {
    spec: GaussianSpec,
    core: BankCore,
    hist: History,
    from_im: bool,
    finished: bool,
    /// Window half-width K (= the output latency).
    pub k: usize,
}

impl StreamingGaussian {
    /// Streaming smoother at (σ, P) with the paper defaults (K = ⌈3σ⌉,
    /// smoothing, scalar lanes). For derivatives, an explicit window, or
    /// the SIMD backend, build a spec and use [`StreamingGaussian::from_spec`]
    /// (or [`GaussianSpec::stream`]).
    pub fn new(sigma: f64, p: usize) -> Result<Self> {
        Self::from_spec(&GaussianSpec::builder(sigma).order(p).build()?)
    }

    /// Streaming processor for a validated spec — the same spec language,
    /// validation, and fit cache as the batch [`GaussianSpec::plan`].
    /// Requires zero extension (a stream has no known right edge to clamp
    /// to) and an in-process backend.
    pub fn from_spec(spec: &GaussianSpec) -> Result<Self> {
        anyhow::ensure!(
            spec.extension == Extension::Zero,
            "streaming is defined over the zero extension; clamp needs the whole signal"
        );
        let backend = stream_backend(spec.backend)?;
        let fit = fit_cache::gaussian_fit(spec.sigma, spec.k, spec.p, spec.beta);
        let terms = crate::plan::gaussian_terms(spec.derivative, &fit);
        Ok(Self {
            spec: *spec,
            core: BankCore::new(spec.k, spec.beta, terms, backend),
            hist: History::default(),
            from_im: spec.derivative == Derivative::First,
            finished: false,
            k: spec.k,
        })
    }

    /// The validated spec this processor was built from.
    pub fn spec(&self) -> &GaussianSpec {
        &self.spec
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; returns the output at index `pushed − 1 − K` once
    /// K + 1 samples have arrived.
    pub fn push(&mut self, x: f64) -> Option<f64> {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        let mut out = None;
        let from_im = self.from_im;
        self.hist.extend(&[x]);
        self.core.process_block(&[x], &self.hist, |re, im| {
            out = Some(if from_im { im } else { re });
        });
        self.hist
            .compact(self.core.pushed().saturating_sub(2 * self.k + 1));
        out
    }

    /// Push a whole block, writing this block's ready outputs into `out`
    /// (cleared first). Bit-identical to pushing sample by sample; runs the
    /// fused bank loop over the block, so throughput matches the batch hot
    /// path.
    pub fn push_block_into(&mut self, xs: &[f64], out: &mut Vec<f64>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        out.clear();
        let from_im = self.from_im;
        self.hist.extend(xs);
        self.core.process_block(xs, &self.hist, |re, im| {
            out.push(if from_im { im } else { re });
        });
        self.hist
            .compact(self.core.pushed().saturating_sub(2 * self.k + 1));
    }

    /// Flush the last K outputs (the batch zero extension) into `out`
    /// (cleared first) and mark the processor spent.
    pub fn finish_into(&mut self, out: &mut Vec<f64>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        out.clear();
        let from_im = self.from_im;
        for _ in 0..self.k {
            self.core.process_block(&[0.0], &self.hist, |re, im| {
                out.push(if from_im { im } else { re });
            });
        }
        self.finished = true;
    }

    /// Allocating convenience form of [`StreamingGaussian::finish_into`].
    pub fn finish(&mut self) -> Vec<f64> {
        let mut out = Vec::new();
        self.finish_into(&mut out);
        out
    }

    /// Rewind to a fresh stream, keeping every fitted constant and buffer.
    pub fn reset(&mut self) {
        self.core.reset();
        self.hist.reset();
        self.finished = false;
    }
}

/// Streaming Morlet wavelet transform (direct method, eq. 54) with latency
/// K, block- or sample-at-a-time, scalar or SIMD lanes.
#[derive(Clone, Debug)]
pub struct StreamingMorlet {
    spec: MorletSpec,
    core: BankCore,
    hist: History,
    /// §3 carrier scale/phase weight — identical to the batch plan's.
    w: Complex<f64>,
    finished: bool,
    /// Window half-width K (= the output latency).
    pub k: usize,
}

/// Build the fused direct-SFT bank of a Morlet spec: the (P_S, P_D) fit from
/// the process-wide cache plus the carrier weight. Shared with the scalogram
/// rows.
pub(crate) fn morlet_bank(spec: &MorletSpec) -> Result<(BankCore, Complex<f64>)> {
    anyhow::ensure!(
        spec.extension == Extension::Zero,
        "streaming is defined over the zero extension; clamp needs the whole signal"
    );
    let backend = stream_backend(spec.backend)?;
    let Method::DirectSft { p_d } = spec.method else {
        anyhow::bail!(
            "only the direct SFT Morlet method is a single causal bank; \
             ASFT/multiply/convolution methods have no streaming form"
        );
    };
    let beta = spec.beta();
    let p_s = fit_cache::optimal_ps(spec.sigma, spec.xi, spec.k, p_d, beta);
    let fit = fit_cache::morlet_direct_fit(spec.sigma, spec.xi, spec.k, p_s, p_d, beta);
    let terms = crate::plan::morlet_terms(&fit);
    // The batch plan's carrier weight for the pure direct method is exactly
    // (1, 0) — no n₀ shift, no attenuation — and the multiply by it is kept
    // so the streaming epilogue runs the identical expression tree as the
    // batch `w * Complex::new(re, im)` (the bit-identity contract), and so
    // a future shifted/attenuated streaming method only has to change w.
    let w = Complex::one();
    Ok((BankCore::new(spec.k, beta, terms, backend), w))
}

impl StreamingMorlet {
    /// Streaming direct-method transform at (σ, ξ, P_D), K = ⌈3σ⌉, scalar
    /// lanes. For the SIMD backend or an explicit window, build a spec and
    /// use [`StreamingMorlet::from_spec`] (or [`MorletSpec::stream`]).
    pub fn new(sigma: f64, xi: f64, p_d: usize) -> Result<Self> {
        Self::from_spec(
            &MorletSpec::builder(sigma, xi)
                .method(Method::DirectSft { p_d })
                .build()?,
        )
    }

    /// Streaming processor for a validated spec — same spec language and
    /// fit cache as the batch [`MorletSpec::plan`]. Requires the direct SFT
    /// method, zero extension, and an in-process backend.
    pub fn from_spec(spec: &MorletSpec) -> Result<Self> {
        let (core, w) = morlet_bank(spec)?;
        Ok(Self {
            spec: *spec,
            k: spec.k,
            core,
            hist: History::default(),
            w,
            finished: false,
        })
    }

    /// The validated spec this processor was built from.
    pub fn spec(&self) -> &MorletSpec {
        &self.spec
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; returns the wavelet coefficient at `pushed − 1 − K`.
    pub fn push(&mut self, x: f64) -> Option<Complex<f64>> {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        let mut out = None;
        let w = self.w;
        self.hist.extend(&[x]);
        self.core.process_block(&[x], &self.hist, |re, im| {
            out = Some(w * Complex::new(re, im));
        });
        self.hist
            .compact(self.core.pushed().saturating_sub(2 * self.k + 1));
        out
    }

    /// Push a whole block, writing this block's ready coefficients into
    /// `out` (cleared first). Bit-identical to the sample path and to the
    /// batch plan.
    pub fn push_block_into(&mut self, xs: &[f64], out: &mut Vec<Complex<f64>>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        out.clear();
        let w = self.w;
        self.hist.extend(xs);
        self.core.process_block(xs, &self.hist, |re, im| {
            out.push(w * Complex::new(re, im));
        });
        self.hist
            .compact(self.core.pushed().saturating_sub(2 * self.k + 1));
    }

    /// Like [`StreamingMorlet::push_block_into`], but split into real and
    /// imaginary planes (the coordinator session wire format).
    pub fn push_block_planes(&mut self, xs: &[f64], re: &mut Vec<f64>, im: &mut Vec<f64>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        re.clear();
        im.clear();
        let w = self.w;
        self.hist.extend(xs);
        self.core.process_block(xs, &self.hist, |r, i| {
            let z = w * Complex::new(r, i);
            re.push(z.re);
            im.push(z.im);
        });
        self.hist
            .compact(self.core.pushed().saturating_sub(2 * self.k + 1));
    }

    /// Flush the last K coefficients (the batch zero extension) into `out`
    /// (cleared first) and mark the processor spent.
    pub fn finish_into(&mut self, out: &mut Vec<Complex<f64>>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        out.clear();
        let w = self.w;
        for _ in 0..self.k {
            self.core.process_block(&[0.0], &self.hist, |re, im| {
                out.push(w * Complex::new(re, im));
            });
        }
        self.finished = true;
    }

    /// Plane-split form of [`StreamingMorlet::finish_into`].
    pub fn finish_planes(&mut self, re: &mut Vec<f64>, im: &mut Vec<f64>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        re.clear();
        im.clear();
        let w = self.w;
        for _ in 0..self.k {
            self.core.process_block(&[0.0], &self.hist, |r, i| {
                let z = w * Complex::new(r, i);
                re.push(z.re);
                im.push(z.im);
            });
        }
        self.finished = true;
    }

    /// Allocating convenience form of [`StreamingMorlet::finish_into`].
    pub fn finish(&mut self) -> Vec<Complex<f64>> {
        let mut out = Vec::new();
        self.finish_into(&mut out);
        out
    }

    /// Rewind to a fresh stream, keeping every fitted constant and buffer.
    pub fn reset(&mut self) {
        self.core.reset();
        self.hist.reset();
        self.finished = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::SignalBuilder;
    use crate::plan::{Backend as PlanBackend, Plan};

    #[test]
    fn streaming_gaussian_is_bit_identical_to_the_plan() {
        let x = SignalBuilder::new(400)
            .sine(0.01, 1.0, 0.2)
            .noise(0.4)
            .build();
        let (sigma, p) = (9.0, 6);
        let spec = GaussianSpec::builder(sigma).order(p).build().unwrap();
        let want = spec.plan().unwrap().execute(&x);
        let mut s = StreamingGaussian::new(sigma, p).unwrap();
        let mut got: Vec<f64> = x.iter().filter_map(|&v| s.push(v)).collect();
        got.extend(s.finish());
        assert_eq!(got, want);
    }

    #[test]
    fn streaming_gaussian_derivatives_match_the_plan() {
        let x = SignalBuilder::new(300).chirp(0.003, 0.08, 1.0).build();
        for d in [Derivative::First, Derivative::Second] {
            let spec = GaussianSpec::builder(7.0)
                .order(5)
                .derivative(d)
                .build()
                .unwrap();
            let want = spec.plan().unwrap().execute(&x);
            let mut s = StreamingGaussian::from_spec(&spec).unwrap();
            let mut got = Vec::new();
            let mut blk = Vec::new();
            for chunk in x.chunks(33) {
                s.push_block_into(chunk, &mut blk);
                got.extend_from_slice(&blk);
            }
            s.finish_into(&mut blk);
            got.extend_from_slice(&blk);
            assert_eq!(got, want, "{d:?}");
        }
    }

    #[test]
    fn streaming_morlet_is_bit_identical_to_the_plan() {
        let x = SignalBuilder::new(360)
            .chirp(0.004, 0.09, 1.0)
            .noise(0.2)
            .build();
        let (sigma, xi, p_d) = (12.0, 6.0, 6);
        let spec = MorletSpec::builder(sigma, xi)
            .method(Method::DirectSft { p_d })
            .build()
            .unwrap();
        let want = spec.plan().unwrap().execute(&x);
        let mut s = StreamingMorlet::new(sigma, xi, p_d).unwrap();
        let mut got: Vec<Complex<f64>> = x.iter().filter_map(|&v| s.push(v)).collect();
        got.extend(s.finish());
        assert_eq!(got, want);
    }

    #[test]
    fn simd_backend_matches_scalar_exactly() {
        let x = SignalBuilder::new(500).sine(0.02, 1.0, 0.0).noise(0.3).build();
        let scalar = GaussianSpec::builder(11.0).order(6).build().unwrap();
        let simd = GaussianSpec::builder(11.0)
            .order(6)
            .backend(PlanBackend::Simd)
            .build()
            .unwrap();
        let mut a = StreamingGaussian::from_spec(&scalar).unwrap();
        let mut b = StreamingGaussian::from_spec(&simd).unwrap();
        let mut out_a = Vec::new();
        let mut out_b = Vec::new();
        a.push_block_into(&x, &mut out_a);
        b.push_block_into(&x, &mut out_b);
        assert_eq!(out_a, out_b);
    }

    #[test]
    fn latency_is_k() {
        let mut s = StreamingGaussian::new(5.0, 4).unwrap();
        let k = s.latency();
        for i in 0..k {
            assert!(s.push(1.0).is_none(), "output before latency at {i}");
        }
        assert!(s.push(1.0).is_some());
    }

    #[test]
    fn reset_allows_exact_reuse() {
        let x = SignalBuilder::new(200).noise(1.0).build();
        let mut s = StreamingMorlet::new(8.0, 6.0, 5).unwrap();
        let mut first = Vec::new();
        s.push_block_into(&x, &mut first);
        let mut tail = Vec::new();
        s.finish_into(&mut tail);
        first.extend_from_slice(&tail);
        s.reset();
        let mut second = Vec::new();
        s.push_block_into(&x, &mut second);
        s.finish_into(&mut tail);
        second.extend_from_slice(&tail);
        assert_eq!(first, second);
    }

    #[test]
    fn stream_constructors_reject_unstreamable_specs() {
        let clamp = GaussianSpec::builder(6.0)
            .extension(Extension::Clamp)
            .build()
            .unwrap();
        assert!(StreamingGaussian::from_spec(&clamp).is_err());
        let runtime = GaussianSpec::builder(6.0)
            .backend(PlanBackend::Runtime)
            .build()
            .unwrap();
        assert!(StreamingGaussian::from_spec(&runtime).is_err());
        let conv = MorletSpec::builder(10.0, 6.0)
            .method(Method::TruncatedConv)
            .build()
            .unwrap();
        assert!(StreamingMorlet::from_spec(&conv).is_err());
    }
}
