//! Bank-based streaming processors: the batch Gaussian/Morlet hot paths as
//! push-style, bounded-state transforms.
//!
//! Both processors are thin wrappers over [`BankCore`] — the streaming form
//! of the fused weighted SFT bank — plus the same plane-selection /
//! carrier-weight epilogues the batch plans apply. They are built from the
//! *same validated specs* and resolve their MMSE weights through the *same
//! process-wide fit cache* as [`crate::plan::GaussianPlan`] /
//! [`crate::plan::MorletPlan`], and their outputs are **bit-identical** to
//! those plans under zero extension ([DESIGN.md §6.2](crate::design);
//! `rust/tests/streaming_parity.rs`).

use super::{stream_backend, BankCore, History};
use crate::dsp::{Complex, Extension};
use crate::morlet::Method;
use crate::plan::cache as fit_cache;
use crate::plan::{Derivative, GaussianSpec, MorletSpec, Precision};
use crate::simd::SimdFloat;
use crate::Result;

/// Precision-tiered bank engine of a streaming processor: the fused bank
/// core plus its delay line, instantiated at the spec's
/// [`Precision`]. The f32 arm narrows each pushed block once into `xbuf`
/// (so the delay line holds exactly the narrowed samples the batch f32
/// path reads) and widens every emission exactly — mirroring the batch
/// plans' f32 paths operation for operation.
#[derive(Clone, Debug)]
pub(crate) enum BankEngine {
    /// f64 tier — the reference path, identical to the pre-tier layout.
    F64 {
        /// Fused bank state.
        core: BankCore<f64>,
        /// Delay line.
        hist: History<f64>,
    },
    /// f32 tier — narrowed delay line + narrowed per-block scratch.
    F32 {
        /// Fused bank state at f32.
        core: BankCore<f32>,
        /// Delay line at f32.
        hist: History<f32>,
        /// Reusable narrowed copy of the pushed block.
        xbuf: Vec<f32>,
    },
}

impl BankEngine {
    pub(crate) fn new(
        precision: Precision,
        k: usize,
        beta: f64,
        terms: Vec<crate::sft::kernel_integral::WeightedTerm>,
        backend: super::Backend,
    ) -> Self {
        match precision {
            Precision::F64 => BankEngine::F64 {
                core: BankCore::new(k, beta, terms, backend),
                hist: History::default(),
            },
            Precision::F32 => BankEngine::F32 {
                core: BankCore::new(k, beta, terms, backend),
                hist: History::default(),
                xbuf: Vec::new(),
            },
            // Processor constructors resolve Auto (crate::tune) before
            // building an engine.
            Precision::Auto => unreachable!("Precision::Auto is resolved before engine construction"),
        }
    }

    /// Ingest a block and emit the ready fused-bank planes, widened to f64
    /// (exact for the f32 tier). `k` is the window half-width the delay
    /// compaction uses.
    pub(crate) fn push_block<F: FnMut(f64, f64)>(&mut self, xs: &[f64], k: usize, mut emit: F) {
        match self {
            BankEngine::F64 { core, hist } => {
                hist.extend(xs);
                core.process_block(xs, hist, &mut emit);
                hist.compact(core.pushed().saturating_sub(2 * k + 1));
            }
            BankEngine::F32 { core, hist, xbuf } => {
                xbuf.clear();
                // The streaming tier boundary: input narrows exactly once,
                // into this engine-owned reused buffer (DESIGN.md §7.1).
                // masft-lint: allow(precision-boundary-casts): sanctioned tier boundary
                xbuf.extend(xs.iter().map(|&v| v as f32));
                hist.extend(xbuf);
                core.process_block(xbuf, hist, |re, im| emit(re as f64, im as f64));
                hist.compact(core.pushed().saturating_sub(2 * k + 1));
            }
        }
    }

    /// Push `k` flush zeros (the batch zero extension), emitting the
    /// withheld tail outputs.
    pub(crate) fn flush<F: FnMut(f64, f64)>(&mut self, k: usize, mut emit: F) {
        match self {
            BankEngine::F64 { core, hist } => {
                for _ in 0..k {
                    core.process_block(&[0.0], hist, &mut emit);
                }
            }
            BankEngine::F32 { core, hist, .. } => {
                for _ in 0..k {
                    core.process_block(&[0.0f32], hist, |re, im| emit(re as f64, im as f64));
                }
            }
        }
    }

    /// Rewind to a fresh stream, keeping constants and buffers.
    pub(crate) fn reset(&mut self) {
        match self {
            BankEngine::F64 { core, hist } => {
                core.reset();
                hist.reset();
            }
            BankEngine::F32 { core, hist, .. } => {
                core.reset();
                hist.reset();
            }
        }
    }
}

/// Streaming Gaussian smoother / differential: the full (σ, P) MMSE bank
/// with latency K, block- or sample-at-a-time, scalar or SIMD lanes, f64
/// or f32 tier.
#[derive(Clone, Debug)]
pub struct StreamingGaussian {
    spec: GaussianSpec,
    engine: BankEngine,
    from_im: bool,
    finished: bool,
    /// Window half-width K (= the output latency).
    pub k: usize,
}

impl StreamingGaussian {
    /// Streaming smoother at (σ, P) with the paper defaults (K = ⌈3σ⌉,
    /// smoothing, scalar lanes). For derivatives, an explicit window, or
    /// the SIMD backend, build a spec and use [`StreamingGaussian::from_spec`]
    /// (or [`GaussianSpec::stream`]).
    pub fn new(sigma: f64, p: usize) -> Result<Self> {
        Self::from_spec(&GaussianSpec::builder(sigma).order(p).build()?)
    }

    /// Streaming processor for a validated spec — the same spec language,
    /// validation, and fit cache as the batch [`GaussianSpec::plan`].
    /// Requires zero extension (a stream has no known right edge to clamp
    /// to) and an in-process backend. The spec's [`Precision`] selects the
    /// tier the bank runs at (outputs stay `f64`, exactly widened).
    pub fn from_spec(spec: &GaussianSpec) -> Result<Self> {
        // Auto knobs resolve here, so the streaming processor lands on the
        // exact concrete tier the batch plan of the same spec resolves to
        // (the bit-identity contract survives Auto).
        let spec = &crate::tune::resolve_gaussian(spec);
        anyhow::ensure!(
            spec.extension == Extension::Zero,
            "streaming is defined over the zero extension; clamp needs the whole signal"
        );
        let backend = stream_backend(spec.backend)?;
        let fit = fit_cache::gaussian_fit(spec.sigma, spec.k, spec.p, spec.beta);
        let terms = crate::plan::gaussian_terms(spec.derivative, &fit);
        Ok(Self {
            spec: *spec,
            engine: BankEngine::new(spec.precision, spec.k, spec.beta, terms, backend),
            from_im: spec.derivative == Derivative::First,
            finished: false,
            k: spec.k,
        })
    }

    /// The validated spec this processor was built from.
    pub fn spec(&self) -> &GaussianSpec {
        &self.spec
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; returns the output at index `pushed − 1 − K` once
    /// K + 1 samples have arrived.
    pub fn push(&mut self, x: f64) -> Option<f64> {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        let mut out = None;
        let from_im = self.from_im;
        self.engine.push_block(&[x], self.k, |re, im| {
            out = Some(if from_im { im } else { re });
        });
        out
    }

    /// Push a whole block, writing this block's ready outputs into `out`
    /// (cleared first). Bit-identical to pushing sample by sample; runs the
    /// fused bank loop over the block, so throughput matches the batch hot
    /// path.
    pub fn push_block_into(&mut self, xs: &[f64], out: &mut Vec<f64>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        out.clear();
        let from_im = self.from_im;
        self.engine.push_block(xs, self.k, |re, im| {
            // masft-lint: allow(no-alloc-in-hot-path): caller-owned buffer, warmed after one block
            out.push(if from_im { im } else { re });
        });
    }

    /// Flush the last K outputs (the batch zero extension) into `out`
    /// (cleared first) and mark the processor spent.
    pub fn finish_into(&mut self, out: &mut Vec<f64>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        out.clear();
        let from_im = self.from_im;
        self.engine.flush(self.k, |re, im| {
            out.push(if from_im { im } else { re });
        });
        self.finished = true;
    }

    /// Allocating convenience form of [`StreamingGaussian::finish_into`].
    pub fn finish(&mut self) -> Vec<f64> {
        let mut out = Vec::new();
        self.finish_into(&mut out);
        out
    }

    /// Rewind to a fresh stream, keeping every fitted constant and buffer.
    pub fn reset(&mut self) {
        self.engine.reset();
        self.finished = false;
    }
}

/// Streaming Morlet wavelet transform (direct method, eq. 54) with latency
/// K, block- or sample-at-a-time, scalar or SIMD lanes, f64 or f32 tier.
#[derive(Clone, Debug)]
pub struct StreamingMorlet {
    spec: MorletSpec,
    engine: MorletEngine,
    finished: bool,
    /// Window half-width K (= the output latency).
    pub k: usize,
}

/// Precision-tiered Morlet engine: the fused bank plus the §3 carrier
/// scale/phase weight, with the carrier product computed **at the tier's
/// precision** before the exact widening — operation for operation the
/// batch [`crate::plan::MorletPlan`] epilogue of that tier.
#[derive(Clone, Debug)]
enum MorletEngine {
    F64 {
        core: BankCore<f64>,
        hist: History<f64>,
        /// §3 carrier scale/phase weight — identical to the batch plan's.
        w: Complex<f64>,
    },
    F32 {
        core: BankCore<f32>,
        hist: History<f32>,
        xbuf: Vec<f32>,
        /// The batch f32 path's narrowed carrier weight.
        w: Complex<f32>,
    },
}

impl MorletEngine {
    fn push_block<F: FnMut(Complex<f64>)>(&mut self, xs: &[f64], k: usize, mut emit: F) {
        match self {
            MorletEngine::F64 { core, hist, w } => {
                let w = *w;
                hist.extend(xs);
                core.process_block(xs, hist, |re, im| emit(w * Complex::new(re, im)));
                hist.compact(core.pushed().saturating_sub(2 * k + 1));
            }
            MorletEngine::F32 {
                core,
                hist,
                xbuf,
                w,
            } => {
                let w = *w;
                xbuf.clear();
                // The streaming tier boundary: input narrows exactly once,
                // into this engine-owned reused buffer (DESIGN.md §7.1).
                // masft-lint: allow(precision-boundary-casts): sanctioned tier boundary
                xbuf.extend(xs.iter().map(|&v| v as f32));
                hist.extend(xbuf);
                core.process_block(xbuf, hist, |re, im| {
                    emit((w * Complex::new(re, im)).cast::<f64>());
                });
                hist.compact(core.pushed().saturating_sub(2 * k + 1));
            }
        }
    }

    fn flush<F: FnMut(Complex<f64>)>(&mut self, k: usize, mut emit: F) {
        match self {
            MorletEngine::F64 { core, hist, w } => {
                let w = *w;
                for _ in 0..k {
                    core.process_block(&[0.0], hist, |re, im| emit(w * Complex::new(re, im)));
                }
            }
            MorletEngine::F32 { core, hist, w, .. } => {
                let w = *w;
                for _ in 0..k {
                    core.process_block(&[0.0f32], hist, |re, im| {
                        emit((w * Complex::new(re, im)).cast::<f64>());
                    });
                }
            }
        }
    }

    fn reset(&mut self) {
        match self {
            MorletEngine::F64 { core, hist, .. } => {
                core.reset();
                hist.reset();
            }
            MorletEngine::F32 { core, hist, .. } => {
                core.reset();
                hist.reset();
            }
        }
    }
}

/// Build the fused direct-SFT bank of a Morlet spec at precision `T`: the
/// (P_S, P_D) fit from the process-wide cache plus the carrier weight.
/// Shared with the scalogram rows.
pub(crate) fn morlet_bank<T: SimdFloat>(spec: &MorletSpec) -> Result<(BankCore<T>, Complex<T>)> {
    anyhow::ensure!(
        spec.extension == Extension::Zero,
        "streaming is defined over the zero extension; clamp needs the whole signal"
    );
    let backend = stream_backend(spec.backend)?;
    let Method::DirectSft { p_d } = spec.method else {
        anyhow::bail!(
            "only the direct SFT Morlet method is a single causal bank; \
             ASFT/multiply/convolution methods have no streaming form"
        );
    };
    let beta = spec.beta();
    let p_s = fit_cache::optimal_ps(spec.sigma, spec.xi, spec.k, p_d, beta);
    let fit = fit_cache::morlet_direct_fit(spec.sigma, spec.xi, spec.k, p_s, p_d, beta);
    let terms = crate::plan::morlet_terms(&fit);
    // The batch plan's carrier weight for the pure direct method is exactly
    // (1, 0) — no n₀ shift, no attenuation — and the multiply by it is kept
    // so the streaming epilogue runs the identical expression tree as the
    // batch `w * Complex::new(re, im)` (the bit-identity contract), and so
    // a future shifted/attenuated streaming method only has to change w.
    // The narrowing cast is exact for (1, 0).
    let w = Complex::<f64>::one().cast::<T>();
    Ok((BankCore::new(spec.k, beta, terms, backend), w))
}

impl StreamingMorlet {
    /// Streaming direct-method transform at (σ, ξ, P_D), K = ⌈3σ⌉, scalar
    /// lanes. For the SIMD backend or an explicit window, build a spec and
    /// use [`StreamingMorlet::from_spec`] (or [`MorletSpec::stream`]).
    pub fn new(sigma: f64, xi: f64, p_d: usize) -> Result<Self> {
        Self::from_spec(
            &MorletSpec::builder(sigma, xi)
                .method(Method::DirectSft { p_d })
                .build()?,
        )
    }

    /// Streaming processor for a validated spec — same spec language and
    /// fit cache as the batch [`MorletSpec::plan`]. Requires the direct SFT
    /// method, zero extension, and an in-process backend. The spec's
    /// [`Precision`] selects the tier the bank and carrier epilogue run at.
    pub fn from_spec(spec: &MorletSpec) -> Result<Self> {
        // Resolve Auto knobs first (same contract as StreamingGaussian).
        let spec = &crate::tune::resolve_morlet(spec);
        let engine = match spec.precision {
            Precision::F64 => {
                let (core, w) = morlet_bank::<f64>(spec)?;
                MorletEngine::F64 {
                    core,
                    hist: History::default(),
                    w,
                }
            }
            Precision::F32 => {
                let (core, w) = morlet_bank::<f32>(spec)?;
                MorletEngine::F32 {
                    core,
                    hist: History::default(),
                    xbuf: Vec::new(),
                    w,
                }
            }
            Precision::Auto => unreachable!("resolved above"),
        };
        Ok(Self {
            spec: *spec,
            k: spec.k,
            engine,
            finished: false,
        })
    }

    /// The validated spec this processor was built from.
    pub fn spec(&self) -> &MorletSpec {
        &self.spec
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; returns the wavelet coefficient at `pushed − 1 − K`.
    pub fn push(&mut self, x: f64) -> Option<Complex<f64>> {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        let mut out = None;
        self.engine.push_block(&[x], self.k, |z| out = Some(z));
        out
    }

    /// Push a whole block, writing this block's ready coefficients into
    /// `out` (cleared first). Bit-identical to the sample path and to the
    /// batch plan of the same precision.
    pub fn push_block_into(&mut self, xs: &[f64], out: &mut Vec<Complex<f64>>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        out.clear();
        // masft-lint: allow(no-alloc-in-hot-path): caller-owned buffer, warmed after one block
        self.engine.push_block(xs, self.k, |z| out.push(z));
    }

    /// Like [`StreamingMorlet::push_block_into`], but split into real and
    /// imaginary planes (the coordinator session wire format).
    pub fn push_block_planes(&mut self, xs: &[f64], re: &mut Vec<f64>, im: &mut Vec<f64>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        re.clear();
        im.clear();
        self.engine.push_block(xs, self.k, |z| {
            re.push(z.re);
            im.push(z.im);
        });
    }

    /// Flush the last K coefficients (the batch zero extension) into `out`
    /// (cleared first) and mark the processor spent.
    pub fn finish_into(&mut self, out: &mut Vec<Complex<f64>>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        out.clear();
        self.engine.flush(self.k, |z| out.push(z));
        self.finished = true;
    }

    /// Plane-split form of [`StreamingMorlet::finish_into`].
    pub fn finish_planes(&mut self, re: &mut Vec<f64>, im: &mut Vec<f64>) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        re.clear();
        im.clear();
        self.engine.flush(self.k, |z| {
            re.push(z.re);
            im.push(z.im);
        });
        self.finished = true;
    }

    /// Allocating convenience form of [`StreamingMorlet::finish_into`].
    pub fn finish(&mut self) -> Vec<Complex<f64>> {
        let mut out = Vec::new();
        self.finish_into(&mut out);
        out
    }

    /// Rewind to a fresh stream, keeping every fitted constant and buffer.
    pub fn reset(&mut self) {
        self.engine.reset();
        self.finished = false;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::SignalBuilder;
    use crate::plan::{Backend as PlanBackend, Plan};

    #[test]
    fn streaming_gaussian_is_bit_identical_to_the_plan() {
        let x = SignalBuilder::new(400)
            .sine(0.01, 1.0, 0.2)
            .noise(0.4)
            .build();
        let (sigma, p) = (9.0, 6);
        let spec = GaussianSpec::builder(sigma).order(p).build().unwrap();
        let want = spec.plan().unwrap().execute(&x);
        let mut s = StreamingGaussian::new(sigma, p).unwrap();
        let mut got: Vec<f64> = x.iter().filter_map(|&v| s.push(v)).collect();
        got.extend(s.finish());
        assert_eq!(got, want);
    }

    #[test]
    fn streaming_gaussian_derivatives_match_the_plan() {
        let x = SignalBuilder::new(300).chirp(0.003, 0.08, 1.0).build();
        for d in [Derivative::First, Derivative::Second] {
            let spec = GaussianSpec::builder(7.0)
                .order(5)
                .derivative(d)
                .build()
                .unwrap();
            let want = spec.plan().unwrap().execute(&x);
            let mut s = StreamingGaussian::from_spec(&spec).unwrap();
            let mut got = Vec::new();
            let mut blk = Vec::new();
            for chunk in x.chunks(33) {
                s.push_block_into(chunk, &mut blk);
                got.extend_from_slice(&blk);
            }
            s.finish_into(&mut blk);
            got.extend_from_slice(&blk);
            assert_eq!(got, want, "{d:?}");
        }
    }

    #[test]
    fn streaming_morlet_is_bit_identical_to_the_plan() {
        let x = SignalBuilder::new(360)
            .chirp(0.004, 0.09, 1.0)
            .noise(0.2)
            .build();
        let (sigma, xi, p_d) = (12.0, 6.0, 6);
        let spec = MorletSpec::builder(sigma, xi)
            .method(Method::DirectSft { p_d })
            .build()
            .unwrap();
        let want = spec.plan().unwrap().execute(&x);
        let mut s = StreamingMorlet::new(sigma, xi, p_d).unwrap();
        let mut got: Vec<Complex<f64>> = x.iter().filter_map(|&v| s.push(v)).collect();
        got.extend(s.finish());
        assert_eq!(got, want);
    }

    #[test]
    fn simd_backend_matches_scalar_exactly() {
        let x = SignalBuilder::new(500).sine(0.02, 1.0, 0.0).noise(0.3).build();
        let scalar = GaussianSpec::builder(11.0).order(6).build().unwrap();
        let simd = GaussianSpec::builder(11.0)
            .order(6)
            .backend(PlanBackend::Simd)
            .build()
            .unwrap();
        let mut a = StreamingGaussian::from_spec(&scalar).unwrap();
        let mut b = StreamingGaussian::from_spec(&simd).unwrap();
        let mut out_a = Vec::new();
        let mut out_b = Vec::new();
        a.push_block_into(&x, &mut out_a);
        b.push_block_into(&x, &mut out_b);
        assert_eq!(out_a, out_b);
    }

    #[test]
    fn f32_stream_matches_f32_plan_exactly() {
        let x = SignalBuilder::new(420).chirp(0.003, 0.07, 1.0).noise(0.3).build();
        for backend in [PlanBackend::PureRust, PlanBackend::Simd] {
            let gspec = GaussianSpec::builder(8.0)
                .order(6)
                .precision(Precision::F32)
                .backend(backend)
                .build()
                .unwrap();
            let want = gspec.plan().unwrap().execute(&x);
            let mut s = StreamingGaussian::from_spec(&gspec).unwrap();
            let mut got = Vec::new();
            let mut blk = Vec::new();
            for chunk in x.chunks(37) {
                s.push_block_into(chunk, &mut blk);
                got.extend_from_slice(&blk);
            }
            s.finish_into(&mut blk);
            got.extend_from_slice(&blk);
            assert_eq!(got, want, "gaussian f32 {backend:?}");

            let mspec = MorletSpec::builder(9.0, 6.0)
                .method(Method::DirectSft { p_d: 5 })
                .precision(Precision::F32)
                .backend(backend)
                .build()
                .unwrap();
            let want = mspec.plan().unwrap().execute(&x);
            let mut s = StreamingMorlet::from_spec(&mspec).unwrap();
            let mut got: Vec<Complex<f64>> = x.iter().filter_map(|&v| s.push(v)).collect();
            got.extend(s.finish());
            assert_eq!(got, want, "morlet f32 {backend:?}");
        }
    }

    #[test]
    fn latency_is_k() {
        let mut s = StreamingGaussian::new(5.0, 4).unwrap();
        let k = s.latency();
        for i in 0..k {
            assert!(s.push(1.0).is_none(), "output before latency at {i}");
        }
        assert!(s.push(1.0).is_some());
    }

    #[test]
    fn reset_allows_exact_reuse() {
        let x = SignalBuilder::new(200).noise(1.0).build();
        let mut s = StreamingMorlet::new(8.0, 6.0, 5).unwrap();
        let mut first = Vec::new();
        s.push_block_into(&x, &mut first);
        let mut tail = Vec::new();
        s.finish_into(&mut tail);
        first.extend_from_slice(&tail);
        s.reset();
        let mut second = Vec::new();
        s.push_block_into(&x, &mut second);
        s.finish_into(&mut tail);
        second.extend_from_slice(&tail);
        assert_eq!(first, second);
    }

    #[test]
    fn stream_constructors_reject_unstreamable_specs() {
        let clamp = GaussianSpec::builder(6.0)
            .extension(Extension::Clamp)
            .build()
            .unwrap();
        assert!(StreamingGaussian::from_spec(&clamp).is_err());
        let runtime = GaussianSpec::builder(6.0)
            .backend(PlanBackend::Runtime)
            .build()
            .unwrap();
        assert!(StreamingGaussian::from_spec(&runtime).is_err());
        let conv = MorletSpec::builder(10.0, 6.0)
            .method(Method::TruncatedConv)
            .build()
            .unwrap();
        assert!(StreamingMorlet::from_spec(&conv).is_err());
    }
}
