//! Multi-scale streaming CWT: one direct-SFT Morlet bank per scale, all
//! rows fed from **one shared delay line** and fanned across
//! [`Parallelism`] workers.
//!
//! Per-scale state is bounded — 10 lane doubles per fitted order plus the
//! shared 2K_max+1 sample history — so an arbitrarily long signal streams
//! in O(Σ_s P_D + K_max) memory. Each row runs exactly the sequential bank
//! code regardless of the worker that picks it up, so output is
//! **bit-identical** to [`crate::plan::ScalogramPlan`] for every
//! parallelism setting (`rust/tests/streaming_parity.rs`).

use super::processors::morlet_bank;
use super::{BankCore, History};
use crate::dsp::Complex;
use crate::exec::{self, Parallelism};
use crate::morlet::{Method, Scalogram};
use crate::plan::{MorletSpec, Precision, ScalogramSpec};
use crate::simd::SimdFloat;
use crate::Result;

/// Below this `rows × block_len` element count, [`Parallelism::Auto`]
/// stays sequential for a pushed block: `exec`'s scoped workers are spawned
/// per call (~10µs each), which would dominate the small real-time blocks a
/// capture loop pushes. An explicit `Threads(n)` is never second-guessed —
/// the same policy as [`crate::exec`]'s chunk gate.
const MIN_AUTO_BLOCK_ELEMS: usize = 8 * 1024;

/// One scale row: a fused Morlet bank plus its carrier weight. The row's
/// window half-width (= its latency) is `core.k()`.
#[derive(Clone, Debug)]
struct ScaleRow<T: SimdFloat> {
    core: BankCore<T>,
    w: Complex<T>,
}

/// Precision-tiered row set: every scale row of one scalogram runs at the
/// spec-level [`Precision`], sharing one delay line of that width. The f32
/// arm narrows each pushed block once into `xbuf` (the shared delay line
/// then holds exactly the narrowed samples every row taps) and computes the
/// carrier product at f32 before the exact widening — the same operation
/// order as the batch f32 scalogram rows.
#[derive(Clone, Debug)]
enum RowSet {
    F64 {
        rows: Vec<ScaleRow<f64>>,
        hist: History<f64>,
    },
    F32 {
        rows: Vec<ScaleRow<f32>>,
        hist: History<f32>,
        xbuf: Vec<f32>,
    },
}

/// Streaming scalogram over a σ grid: latency K_s per scale row (each row
/// emits its magnitudes as soon as its own window fills), shared history
/// sized by the largest scale.
#[derive(Clone, Debug)]
pub struct StreamingScalogram {
    spec: ScalogramSpec,
    rows: RowSet,
    k_max: usize,
    pushed: usize,
    parallelism: Parallelism,
    finished: bool,
}

fn build_rows<T: SimdFloat>(spec: &ScalogramSpec) -> Result<Vec<ScaleRow<T>>> {
    spec.sigmas
        .iter()
        .map(|&sigma| {
            let ms = MorletSpec::builder(sigma, spec.xi)
                .method(Method::DirectSft { p_d: spec.p_d })
                .extension(spec.extension)
                .backend(spec.backend)
                .precision(spec.precision)
                .build()?;
            let (core, w) = morlet_bank::<T>(&ms)?;
            Ok(ScaleRow { core, w })
        })
        .collect()
}

/// Advance every row of one tier over a (tier-width) block, fanned across
/// `par` workers — each row runs exactly the sequential bank code, so the
/// fan-out never changes values. Magnitudes are computed on the exactly
/// widened carrier product, matching the batch rows of the same tier.
fn process_rows<T: SimdFloat>(
    rows: &mut [ScaleRow<T>],
    out: &mut Scalogram,
    xs: &[T],
    hist: &History<T>,
    par: Parallelism,
) {
    let mut slots: Vec<(&mut ScaleRow<T>, &mut Vec<f64>)> =
        rows.iter_mut().zip(out.rows.iter_mut()).collect();
    exec::for_each_slot(par, &mut slots, || (), |_i, slot, _| {
        let (row, out_row) = slot;
        out_row.clear();
        let w = row.w;
        row.core.process_block(xs, hist, |re, im| {
            out_row.push((w * Complex::new(re, im)).cast::<f64>().norm());
        });
    });
}

/// Flush every row's tail (its own K_s-zero extension); see
/// [`StreamingScalogram::finish_into`].
fn flush_rows<T: SimdFloat>(
    rows: &mut [ScaleRow<T>],
    out: &mut Scalogram,
    hist: &History<T>,
    par: Parallelism,
) {
    let mut slots: Vec<(&mut ScaleRow<T>, &mut Vec<f64>)> =
        rows.iter_mut().zip(out.rows.iter_mut()).collect();
    exec::for_each_slot(par, &mut slots, || (), |_i, slot, _| {
        let (row, out_row) = slot;
        out_row.clear();
        let w = row.w;
        // Zero flush taps only real (or pre-stream) history indices, so
        // the zeros themselves never enter the shared delay line.
        for _ in 0..row.core.k() {
            row.core.process_block(&[T::ZERO], hist, |re, im| {
                out_row.push((w * Complex::new(re, im)).cast::<f64>().norm());
            });
        }
    });
}

impl StreamingScalogram {
    /// Streaming processor for a validated spec — the same spec language,
    /// per-row fits, and fit cache as the batch [`ScalogramSpec::plan`].
    /// Requires zero extension and an in-process backend. The spec's
    /// [`Precision`] selects the tier every row (and the shared delay line)
    /// runs at.
    pub fn from_spec(spec: &ScalogramSpec) -> Result<Self> {
        // Resolve Auto knobs first (same contract as StreamingGaussian):
        // every row inherits one concrete backend/precision pair.
        let spec = &crate::tune::resolve_scalogram(spec);
        let rows = match spec.precision {
            Precision::F64 => RowSet::F64 {
                rows: build_rows::<f64>(spec)?,
                hist: History::default(),
            },
            Precision::F32 => RowSet::F32 {
                rows: build_rows::<f32>(spec)?,
                hist: History::default(),
                xbuf: Vec::new(),
            },
            Precision::Auto => unreachable!("resolved above"),
        };
        let k_max = match &rows {
            RowSet::F64 { rows, .. } => rows.iter().map(|r| r.core.k()).max().unwrap_or(0),
            RowSet::F32 { rows, .. } => rows.iter().map(|r| r.core.k()).max().unwrap_or(0),
        };
        Ok(Self {
            parallelism: spec.parallelism,
            spec: spec.clone(),
            rows,
            k_max,
            pushed: 0,
            finished: false,
        })
    }

    /// The validated spec this processor was built from.
    pub fn spec(&self) -> &ScalogramSpec {
        &self.spec
    }

    /// Worst-case output latency in samples: the largest scale's K. Each
    /// row individually has latency `⌈3σ_s⌉` (its own window half-width).
    pub fn latency(&self) -> usize {
        self.k_max
    }

    /// Override the worker fan-out over scale rows (kept in sync on the
    /// spec, mirroring [`crate::plan::ScalogramPlan::with_parallelism`]).
    pub fn with_parallelism(mut self, par: Parallelism) -> Self {
        self.parallelism = par;
        self.spec.parallelism = par;
        self
    }

    /// Push a whole block, writing each row's newly ready magnitudes into
    /// `out.rows` (reshaped to this grid, rows cleared first). Rows fill at
    /// different rates while their windows warm up; concatenating the rows
    /// emitted across calls (plus [`StreamingScalogram::finish_into`])
    /// reproduces the batch scalogram of the same precision exactly.
    pub fn push_block_into(&mut self, xs: &[f64], out: &mut Scalogram) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        self.shape_output(out);
        let par = self.block_parallelism(xs.len());
        match &mut self.rows {
            RowSet::F64 { rows, hist } => {
                hist.extend(xs);
                process_rows(rows, out, xs, hist, par);
            }
            RowSet::F32 { rows, hist, xbuf } => {
                xbuf.clear();
                // The streaming tier boundary: input narrows exactly once,
                // into the ONE f32 delay line all rows share (DESIGN.md §7.1).
                // masft-lint: allow(precision-boundary-casts): sanctioned tier boundary
                xbuf.extend(xs.iter().map(|&v| v as f32));
                hist.extend(xbuf);
                process_rows(rows, out, xbuf, hist, par);
            }
        }
        self.pushed += xs.len();
        let keep_from = self.pushed.saturating_sub(2 * self.k_max + 1);
        match &mut self.rows {
            RowSet::F64 { hist, .. } => hist.compact(keep_from),
            RowSet::F32 { hist, .. } => hist.compact(keep_from),
        }
    }

    /// Flush every row's tail (its own K_s-zero extension) into `out`
    /// (rows cleared first) and mark the processor spent.
    pub fn finish_into(&mut self, out: &mut Scalogram) {
        assert!(!self.finished, "processor is spent after finish(); call reset()");
        self.shape_output(out);
        let par = self.block_parallelism(self.k_max);
        match &mut self.rows {
            RowSet::F64 { rows, hist } => flush_rows(rows, out, hist, par),
            RowSet::F32 { rows, hist, .. } => flush_rows(rows, out, hist, par),
        }
        self.finished = true;
    }

    /// Rewind to a fresh stream, keeping every fitted constant and buffer.
    pub fn reset(&mut self) {
        match &mut self.rows {
            RowSet::F64 { rows, hist } => {
                for row in rows.iter_mut() {
                    row.core.reset();
                }
                hist.reset();
            }
            RowSet::F32 { rows, hist, .. } => {
                for row in rows.iter_mut() {
                    row.core.reset();
                }
                hist.reset();
            }
        }
        self.pushed = 0;
        self.finished = false;
    }

    /// Number of scale rows.
    fn row_count(&self) -> usize {
        match &self.rows {
            RowSet::F64 { rows, .. } => rows.len(),
            RowSet::F32 { rows, .. } => rows.len(),
        }
    }

    /// The effective fan-out for one pushed block: `Auto` degrades to
    /// sequential when `rows × block_len` is too small to amortize the
    /// per-call thread spawns (values are unaffected either way — the knob
    /// only trades wall-clock for occupancy).
    fn block_parallelism(&self, block_len: usize) -> Parallelism {
        if self.parallelism == Parallelism::Auto
            && block_len.saturating_mul(self.row_count()) < MIN_AUTO_BLOCK_ELEMS
        {
            return Parallelism::Sequential;
        }
        self.parallelism
    }

    /// Point `out` at this grid (ξ, σ list, one row per scale) without
    /// touching row contents beyond resizing.
    fn shape_output(&self, out: &mut Scalogram) {
        out.xi = self.spec.xi;
        out.sigmas.clear();
        out.sigmas.extend_from_slice(&self.spec.sigmas);
        out.rows.resize_with(self.row_count(), Vec::new);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::SignalBuilder;
    use crate::plan::Plan;

    fn accumulate(sg: &mut StreamingScalogram, x: &[f64], block: usize) -> Scalogram {
        let mut acc = Scalogram::default();
        let mut out = Scalogram::default();
        for chunk in x.chunks(block) {
            sg.push_block_into(chunk, &mut out);
            acc.append_rows(&out);
        }
        sg.finish_into(&mut out);
        acc.append_rows(&out);
        acc
    }

    #[test]
    fn streaming_scalogram_is_bit_identical_to_the_plan() {
        let x = SignalBuilder::new(700)
            .chirp(0.002, 0.05, 1.0)
            .noise(0.2)
            .build();
        let spec = ScalogramSpec::builder(6.0)
            .sigmas(&[6.0, 11.0, 23.0])
            .order(5)
            .build()
            .unwrap();
        let want = spec.plan().unwrap().execute(&x);
        let mut sg = StreamingScalogram::from_spec(&spec).unwrap();
        let got = accumulate(&mut sg, &x, 64);
        assert_eq!(got.rows.len(), want.rows.len());
        for (s, (g, w)) in got.rows.iter().zip(want.rows.iter()).enumerate() {
            assert_eq!(g, w, "scale {s}");
        }
    }

    #[test]
    fn parallel_rows_match_sequential_exactly() {
        let x = SignalBuilder::new(400).chirp(0.004, 0.06, 1.0).build();
        let spec = ScalogramSpec::builder(6.0)
            .sigmas(&[5.0, 9.0, 14.0, 20.0])
            .build()
            .unwrap();
        let mut seq = StreamingScalogram::from_spec(&spec)
            .unwrap()
            .with_parallelism(Parallelism::Sequential);
        let want = accumulate(&mut seq, &x, 50);
        let mut par = StreamingScalogram::from_spec(&spec)
            .unwrap()
            .with_parallelism(Parallelism::Threads(4));
        let got = accumulate(&mut par, &x, 50);
        for (g, w) in got.rows.iter().zip(want.rows.iter()) {
            assert_eq!(g, w);
        }
    }

    #[test]
    fn f32_streaming_scalogram_matches_f32_plan() {
        let x = SignalBuilder::new(500).chirp(0.002, 0.05, 1.0).noise(0.2).build();
        let spec = ScalogramSpec::builder(6.0)
            .sigmas(&[6.0, 11.0, 23.0])
            .order(5)
            .precision(Precision::F32)
            .build()
            .unwrap();
        let want = spec.plan().unwrap().execute(&x);
        let mut sg = StreamingScalogram::from_spec(&spec).unwrap();
        let got = accumulate(&mut sg, &x, 64);
        assert_eq!(got.rows.len(), want.rows.len());
        for (s, (g, w)) in got.rows.iter().zip(want.rows.iter()).enumerate() {
            assert_eq!(g, w, "scale {s}");
        }
    }

    #[test]
    fn reset_allows_exact_reuse() {
        let x = SignalBuilder::new(300).noise(1.0).build();
        let spec = ScalogramSpec::builder(6.0).sigmas(&[7.0, 13.0]).build().unwrap();
        let mut sg = StreamingScalogram::from_spec(&spec).unwrap();
        let first = accumulate(&mut sg, &x, 41);
        sg.reset();
        let second = accumulate(&mut sg, &x, 97);
        for (a, b) in first.rows.iter().zip(second.rows.iter()) {
            assert_eq!(a, b);
        }
    }
}
