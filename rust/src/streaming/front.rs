//! Plan integration: the same validated [`TransformSpec`]s that build batch
//! plans build streaming processors — `spec.stream()` is the push-style
//! sibling of `spec.plan()`, resolved through the same process-wide fit
//! cache, so batch and streaming stay one API.

use super::{StreamingGaussian, StreamingMorlet, StreamingScalogram};
use crate::morlet::Scalogram;
use crate::plan::{Gabor2dSpec, GaussianSpec, MorletSpec, ScalogramSpec, TransformSpec};
use crate::Result;

/// A prepared streaming transform: the push-style counterpart of
/// [`crate::plan::Plan`], one variant per streamable spec family.
///
/// Use the uniform [`StreamingPlan::push_block`] / [`StreamingPlan::finish`]
/// interface (the coordinator session path), or match on the variant for
/// the typed per-processor APIs.
#[derive(Clone, Debug)]
pub enum StreamingPlan {
    /// Gaussian smoothing / differential stream.
    Gaussian(StreamingGaussian),
    /// Morlet direct-SFT stream.
    Morlet(StreamingMorlet),
    /// Multi-scale scalogram stream.
    Scalogram(StreamingScalogram),
}

/// Reusable per-block output of [`StreamingPlan::push_block`]: which fields
/// fill depends on the variant (`re` for Gaussian, `re`+`im` for Morlet,
/// `scalogram` for scalograms; the unused fields are cleared). Buffers grow
/// to the block high-water mark and are then reused.
#[derive(Clone, Debug, Default)]
pub struct BlockOut {
    /// Real output plane (Gaussian value / Morlet real part).
    pub re: Vec<f64>,
    /// Imaginary output plane (Morlet only).
    pub im: Vec<f64>,
    /// Per-scale magnitude rows (scalogram only).
    pub scalogram: Scalogram,
}

impl BlockOut {
    /// Total ready output samples carried by this block: the plane length
    /// for Gaussian/Morlet streams (one sample per complex pair), summed
    /// over every scale row for scalogram streams.
    pub fn len(&self) -> usize {
        self.re.len() + self.scalogram.rows.iter().map(Vec::len).sum::<usize>()
    }

    /// True when no output surface carries a sample.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl StreamingPlan {
    /// Worst-case output latency in samples (the per-row K for single
    /// transforms, K_max for scalograms).
    pub fn latency(&self) -> usize {
        match self {
            StreamingPlan::Gaussian(g) => g.latency(),
            StreamingPlan::Morlet(m) => m.latency(),
            StreamingPlan::Scalogram(s) => s.latency(),
        }
    }

    /// Push a block of samples, refilling `out` with this block's ready
    /// outputs (unused surfaces cleared).
    pub fn push_block(&mut self, xs: &[f64], out: &mut BlockOut) {
        match self {
            StreamingPlan::Gaussian(g) => {
                g.push_block_into(xs, &mut out.re);
                out.im.clear();
                out.scalogram.rows.clear();
            }
            StreamingPlan::Morlet(m) => {
                m.push_block_planes(xs, &mut out.re, &mut out.im);
                out.scalogram.rows.clear();
            }
            StreamingPlan::Scalogram(s) => {
                s.push_block_into(xs, &mut out.scalogram);
                out.re.clear();
                out.im.clear();
            }
        }
    }

    /// Flush the tail (the batch zero extension) into `out` and mark the
    /// stream spent; [`StreamingPlan::reset`] rewinds for reuse.
    pub fn finish(&mut self, out: &mut BlockOut) {
        match self {
            StreamingPlan::Gaussian(g) => {
                g.finish_into(&mut out.re);
                out.im.clear();
                out.scalogram.rows.clear();
            }
            StreamingPlan::Morlet(m) => {
                m.finish_planes(&mut out.re, &mut out.im);
                out.scalogram.rows.clear();
            }
            StreamingPlan::Scalogram(s) => {
                s.finish_into(&mut out.scalogram);
                out.re.clear();
                out.im.clear();
            }
        }
    }

    /// Rewind to a fresh stream, keeping every fitted constant and buffer.
    pub fn reset(&mut self) {
        match self {
            StreamingPlan::Gaussian(g) => g.reset(),
            StreamingPlan::Morlet(m) => m.reset(),
            StreamingPlan::Scalogram(s) => s.reset(),
        }
    }
}

impl GaussianSpec {
    /// Build a streaming processor for this spec (the push-style sibling of
    /// [`GaussianSpec::plan`]). Requires zero extension and an in-process
    /// backend.
    pub fn stream(&self) -> Result<StreamingGaussian> {
        StreamingGaussian::from_spec(self)
    }
}

impl MorletSpec {
    /// Build a streaming processor for this spec (the push-style sibling of
    /// [`MorletSpec::plan`]). Requires the direct SFT method, zero
    /// extension, and an in-process backend.
    pub fn stream(&self) -> Result<StreamingMorlet> {
        StreamingMorlet::from_spec(self)
    }
}

impl ScalogramSpec {
    /// Build a streaming processor for this spec (the push-style sibling of
    /// [`ScalogramSpec::plan`]). Requires zero extension and an in-process
    /// backend.
    pub fn stream(&self) -> Result<StreamingScalogram> {
        StreamingScalogram::from_spec(self)
    }
}

impl Gabor2dSpec {
    /// 2-D Gabor banks have no streaming form (images arrive whole); this
    /// always fails and exists so the spec family is total over `stream`.
    pub fn stream(&self) -> Result<StreamingPlan> {
        anyhow::bail!("2-D Gabor banks have no streaming form; execute the batch plan per image")
    }
}

impl TransformSpec {
    /// Build the streaming processor for any streamable spec — the unified
    /// entry point mirroring the batch plan constructors. 2-D Gabor specs
    /// are rejected.
    pub fn stream(&self) -> Result<StreamingPlan> {
        match self {
            TransformSpec::Gaussian(g) => Ok(StreamingPlan::Gaussian(g.stream()?)),
            TransformSpec::Morlet(m) => Ok(StreamingPlan::Morlet(m.stream()?)),
            TransformSpec::Scalogram(s) => Ok(StreamingPlan::Scalogram(s.stream()?)),
            TransformSpec::Gabor2d(g) => g.stream(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::SignalBuilder;
    use crate::plan::Plan;

    #[test]
    fn transform_spec_stream_round_trips_every_family() {
        let x = SignalBuilder::new(260).sine(0.02, 1.0, 0.1).noise(0.2).build();

        let g: TransformSpec = GaussianSpec::builder(6.0).build().unwrap().into();
        let mut sp = g.stream().unwrap();
        let mut out = BlockOut::default();
        sp.push_block(&x, &mut out);
        let mut n = out.re.len();
        sp.finish(&mut out);
        n += out.re.len();
        assert_eq!(n, x.len());

        let m: TransformSpec = MorletSpec::builder(8.0, 6.0).build().unwrap().into();
        let mut sp = m.stream().unwrap();
        sp.push_block(&x, &mut out);
        assert_eq!(out.re.len(), out.im.len());
        let want = MorletSpec::builder(8.0, 6.0)
            .build()
            .unwrap()
            .plan()
            .unwrap()
            .execute(&x);
        for (i, z) in want.iter().take(out.re.len()).enumerate() {
            assert_eq!(out.re[i], z.re, "i={i}");
            assert_eq!(out.im[i], z.im, "i={i}");
        }

        let s: TransformSpec = ScalogramSpec::builder(6.0)
            .sigmas(&[5.0, 9.0])
            .build()
            .unwrap()
            .into();
        let mut sp = s.stream().unwrap();
        sp.push_block(&x, &mut out);
        assert_eq!(out.scalogram.rows.len(), 2);
        assert!(out.re.is_empty());

        let gb: TransformSpec = Gabor2dSpec::builder(3.0, 0.5).build().unwrap().into();
        assert!(gb.stream().is_err());
    }

    #[test]
    fn reset_makes_a_stream_plan_reusable() {
        let x = SignalBuilder::new(150).noise(1.0).build();
        let spec: TransformSpec = GaussianSpec::builder(5.0).build().unwrap().into();
        let mut sp = spec.stream().unwrap();
        let mut out = BlockOut::default();
        sp.push_block(&x, &mut out);
        let first = out.re.clone();
        sp.finish(&mut out);
        sp.reset();
        sp.push_block(&x, &mut out);
        assert_eq!(out.re, first);
    }
}
