//! The streaming weighted-bank engine: the batch hot path
//! ([`crate::sft::kernel_integral::weighted_bank_into`]) re-expressed as an
//! online filter with bounded state.
//!
//! The batch bank already *is* a recursive filter (its §Perf iteration 6
//! form): per lane it carries the demodulated window state
//!
//! ```text
//! w̃[i] = e^{-iω}·w̃[i−1] + x[i+K]·e^{iωK} − x[i−K−1]·e^{-iω(K+1)}
//! ```
//!
//! whose inputs are the newest sample and one 2K+1-delayed sample. This
//! module runs that recurrence push-by-push with the **identical** per-lane
//! expression tree, warm-up loop, and accumulation order as the batch code,
//! so streaming output is bit-identical to the batch plans — the central
//! claim of [DESIGN.md §6](crate::design), proven in
//! `rust/tests/streaming_parity.rs` and the unit tests below. Keep
//! [`lane_pass`] in lockstep with the scalar and SIMD batch bodies when
//! editing any of the three.

use super::Backend;
use crate::dsp::Float;
use crate::sft::kernel_integral::{Rotor, WeightedTerm};
use crate::simd::SimdFloat;

/// Absolute-indexed sample history with amortized O(1) compaction: the
/// bounded delay-line storage shared by all lanes of a processor (and by all
/// scale rows of a [`super::StreamingScalogram`]). Generic over the
/// precision tier: an f32 stream keeps its delay line in f32, so the
/// delayed tap is exactly the narrowed sample the batch f32 path reads.
#[derive(Clone, Debug, Default)]
pub(crate) struct History<T> {
    buf: Vec<T>,
    /// Absolute signal index of `buf[0]`.
    start: usize,
}

impl<T: Float> History<T> {
    /// Append a block of samples.
    pub fn extend(&mut self, xs: &[T]) {
        self.buf.extend_from_slice(xs);
    }

    /// The sample at absolute index `idx`; zero for indices before the
    /// stream start (the left zero extension). Indices already compacted
    /// away or not yet pushed are a caller bug.
    #[inline]
    pub fn get(&self, idx: isize) -> T {
        if idx < 0 {
            return T::ZERO;
        }
        let idx = idx as usize;
        debug_assert!(
            idx >= self.start && idx - self.start < self.buf.len(),
            "history tap {idx} outside retained window [{}, {})",
            self.start,
            self.start + self.buf.len()
        );
        self.buf[idx - self.start]
    }

    /// Drop samples before absolute index `keep_from`. Amortized: the front
    /// is only drained once the dead prefix dominates, so per-push cost is
    /// O(1) and resident storage stays within 2× the live window.
    pub fn compact(&mut self, keep_from: usize) {
        if keep_from > self.start {
            let dead = keep_from - self.start;
            if dead >= self.buf.len() / 2 && dead >= 64 {
                self.buf.drain(..dead);
                self.start = keep_from;
            }
        }
    }

    /// Rewind to an empty history without releasing capacity.
    pub fn reset(&mut self) {
        self.buf.clear();
        self.start = 0;
    }
}

/// Number of state slices per lane in the flat SoA buffer (same layout as
/// the batch `weighted_bank_into` lane buffer).
const SLICES: usize = 10;

/// Streaming state of one fused weighted SFT bank: the per-lane filter state
/// of the batch hot path, advanced one sample at a time. Does not own its
/// delay storage — callers pass a [`History`] so several banks (the
/// scalogram's scale rows) can share one. Generic over the precision tier:
/// the f32 instantiation is the streaming form of the batch
/// [`crate::plan::Precision::F32`] paths, with identical per-lane
/// arithmetic at f32 width.
#[derive(Clone, Debug)]
pub(crate) struct BankCore<T: SimdFloat> {
    k: usize,
    beta: f64,
    backend: Backend,
    terms: Vec<WeightedTerm>,
    /// Flat SoA lane state, `SLICES × lanes`: w_re, w_im, pole_re, pole_im,
    /// cin_re, cin_im, cout_re, cout_im, mw, lw — identical layout (and
    /// identical warm-up/update arithmetic) to the batch lane buffer.
    state: Vec<T>,
    /// Per-lane warm-up twiddle generators (the batch warm-up rotors),
    /// consumed during the first K pushes.
    warm: Vec<Rotor<T>>,
    /// Samples pushed so far = the absolute index of the next sample.
    pushed: usize,
}

impl<T: SimdFloat> BankCore<T> {
    /// A bank at window half-width `k`, base frequency `beta`, weighted
    /// `terms` (one lane per term).
    pub fn new(k: usize, beta: f64, terms: Vec<WeightedTerm>, backend: Backend) -> Self {
        let lanes = terms.len();
        let mut state = vec![T::ZERO; SLICES * lanes];
        init_constants(&mut state, lanes, k, beta, &terms);
        let warm = terms
            .iter()
            .map(|t| Rotor::<T>::new(beta * t.p, beta * t.p))
            .collect();
        Self {
            k,
            beta,
            backend,
            terms,
            state,
            warm,
            pushed: 0,
        }
    }

    /// Window half-width K (= the output latency).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Samples pushed so far.
    pub fn pushed(&self) -> usize {
        self.pushed
    }

    /// Rewind to a fresh stream: zero the filter state, re-seed the warm-up
    /// rotors, keep every constant and allocation.
    pub fn reset(&mut self) {
        let lanes = self.terms.len();
        for v in self.state[..2 * lanes].iter_mut() {
            *v = T::ZERO;
        }
        for (rot, t) in self.warm.iter_mut().zip(self.terms.iter()) {
            *rot = Rotor::<T>::new(self.beta * t.p, self.beta * t.p);
        }
        self.pushed = 0;
    }

    /// Advance the bank over a block of samples, emitting `(acc_re, acc_im)`
    /// per ready output (the fused bank planes — identical values to the
    /// batch `re`/`im` outputs at the same signal index). `hist` must
    /// already contain every sample of `xs` when the block carries real
    /// samples; flush blocks of zeros need not be appended — their delay
    /// taps always land on real (or pre-stream) indices.
    pub fn process_block<F: FnMut(T, T)>(&mut self, xs: &[T], hist: &History<T>, mut emit: F) {
        let lanes = self.terms.len();
        let mut i = 0;
        // Warm-up: the first K pushes only accumulate w̃[−1], with the exact
        // rotor sequence of the batch warm-up loop.
        while i < xs.len() && self.pushed < self.k {
            let x = xs[i];
            let (w_re, rest) = self.state.split_at_mut(lanes);
            let (w_im, _) = rest.split_at_mut(lanes);
            for (j, rot) in self.warm.iter_mut().enumerate() {
                let w = rot.next_val();
                w_re[j] += w.re * x;
                w_im[j] += w.im * x;
            }
            self.pushed += 1;
            i += 1;
        }
        // Steady state: one recurrence step per sample. Output index is
        // pushed − K; the leaving sample is x[pushed − (2K+1)].
        let d = (2 * self.k + 1) as isize;
        for &x in &xs[i..] {
            let x_out = hist.get(self.pushed as isize - d);
            let (acc_re, acc_im) = lane_pass(&mut self.state, lanes, self.backend, x, x_out);
            self.pushed += 1;
            emit(acc_re, acc_im);
        }
    }
}

/// Fill the constant sections of the lane state — the exact constants (and
/// expressions) of the batch bank initialization (f64-derived, narrowed
/// once, like the batch generic body).
fn init_constants<T: Float>(
    state: &mut [T],
    lanes: usize,
    k: usize,
    beta: f64,
    terms: &[WeightedTerm],
) {
    let (_w_re, rest) = state.split_at_mut(lanes);
    let (_w_im, rest) = rest.split_at_mut(lanes);
    let (pole_re, rest) = rest.split_at_mut(lanes);
    let (pole_im, rest) = rest.split_at_mut(lanes);
    let (cin_re, rest) = rest.split_at_mut(lanes);
    let (cin_im, rest) = rest.split_at_mut(lanes);
    let (cout_re, rest) = rest.split_at_mut(lanes);
    let (cout_im, rest) = rest.split_at_mut(lanes);
    let (mw, lw) = rest.split_at_mut(lanes);
    for (j, t) in terms.iter().enumerate() {
        let om = beta * t.p;
        pole_re[j] = T::from_f64(om.cos());
        pole_im[j] = T::from_f64(-om.sin()); // e^{-iω}
        let thk = om * k as f64;
        cin_re[j] = T::from_f64(thk.cos());
        cin_im[j] = T::from_f64(thk.sin()); // e^{iωK}
        let tho = -om * (k as f64 + 1.0);
        cout_re[j] = T::from_f64(tho.cos());
        cout_im[j] = T::from_f64(tho.sin()); // e^{-iω(K+1)}
        mw[j] = T::from_f64(t.m);
        lw[j] = T::from_f64(t.l);
    }
}

/// One per-sample pass over every lane: the recurrence step plus the
/// weighted output reduction. The scalar arm is the batch scalar body
/// verbatim; the SIMD arm is the batch [`crate::simd::weighted_bank_into`]
/// body verbatim ([`crate::simd::F64x4`]/[`crate::simd::F32x8`] blocks per
/// the precision, scalar remainder, ascending-lane sequential reduction) —
/// so Scalar, Simd, and both batch paths all produce bit-identical values
/// at either precision tier.
#[inline(always)]
fn lane_pass<T: SimdFloat>(
    state: &mut [T],
    lanes: usize,
    backend: Backend,
    x_in: T,
    x_out: T,
) -> (T, T) {
    let (w_re, rest) = state.split_at_mut(lanes);
    let (w_im, rest) = rest.split_at_mut(lanes);
    let (pole_re, rest) = rest.split_at_mut(lanes);
    let (pole_im, rest) = rest.split_at_mut(lanes);
    let (cin_re, rest) = rest.split_at_mut(lanes);
    let (cin_im, rest) = rest.split_at_mut(lanes);
    let (cout_re, rest) = rest.split_at_mut(lanes);
    let (cout_im, rest) = rest.split_at_mut(lanes);
    let (mw, lw) = rest.split_at_mut(lanes);
    let mut acc_re = T::ZERO;
    let mut acc_im = T::ZERO;
    match backend {
        Backend::Scalar => {
            for j in 0..lanes {
                let (pr, pi) = (pole_re[j], pole_im[j]);
                let (wr0, wi0) = (w_re[j], w_im[j]);
                let wr = pr * wr0 - pi * wi0 + x_in * cin_re[j] - x_out * cout_re[j];
                let wi = pr * wi0 + pi * wr0 + x_in * cin_im[j] - x_out * cout_im[j];
                w_re[j] = wr;
                w_im[j] = wi;
                acc_re += mw[j] * wr;
                acc_im -= lw[j] * wi;
            }
        }
        Backend::Simd => {
            let width = T::Vec::WIDTH;
            let blocks = lanes - lanes % width;
            let xin_v = T::Vec::splat(x_in);
            let xout_v = T::Vec::splat(x_out);
            let mut j = 0;
            while j < blocks {
                let pr = T::Vec::load(&pole_re[j..]);
                let pi = T::Vec::load(&pole_im[j..]);
                let wr0 = T::Vec::load(&w_re[j..]);
                let wi0 = T::Vec::load(&w_im[j..]);
                let wr = pr * wr0 - pi * wi0 + xin_v * T::Vec::load(&cin_re[j..])
                    - xout_v * T::Vec::load(&cout_re[j..]);
                let wi = pr * wi0 + pi * wr0 + xin_v * T::Vec::load(&cin_im[j..])
                    - xout_v * T::Vec::load(&cout_im[j..]);
                wr.store(&mut w_re[j..]);
                wi.store(&mut w_im[j..]);
                let prod_re = T::Vec::load(&mw[j..]) * wr;
                let prod_im = T::Vec::load(&lw[j..]) * wi;
                for t in 0..width {
                    acc_re += prod_re.lane(t);
                    acc_im -= prod_im.lane(t);
                }
                j += width;
            }
            while j < lanes {
                let (pr, pi) = (pole_re[j], pole_im[j]);
                let (wr0, wi0) = (w_re[j], w_im[j]);
                let wr = pr * wr0 - pi * wi0 + x_in * cin_re[j] - x_out * cout_re[j];
                let wi = pr * wi0 + pi * wr0 + x_in * cin_im[j] - x_out * cout_im[j];
                w_re[j] = wr;
                w_im[j] = wi;
                acc_re += mw[j] * wr;
                acc_im -= lw[j] * wi;
                j += 1;
            }
        }
    }
    (acc_re, acc_im)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::gaussian_noise;
    use crate::sft::kernel_integral;

    fn terms(count: usize) -> Vec<WeightedTerm> {
        (0..count)
            .map(|j| WeightedTerm {
                p: j as f64 + 0.5 * (j % 2) as f64,
                m: 0.7 - 0.11 * j as f64,
                l: -0.2 + 0.07 * j as f64,
            })
            .collect()
    }

    /// Drive `n_real` samples plus the K-zero flush through a bank, with the
    /// stream cut into `block` sized pieces.
    fn stream_bank<T: SimdFloat>(
        core: &mut BankCore<T>,
        hist: &mut History<T>,
        x: &[T],
        block: usize,
    ) -> (Vec<T>, Vec<T>) {
        let mut re = Vec::new();
        let mut im = Vec::new();
        for chunk in x.chunks(block.max(1)) {
            hist.extend(chunk);
            core.process_block(chunk, hist, |r, i| {
                re.push(r);
                im.push(i);
            });
            hist.compact(core.pushed().saturating_sub(2 * core.k() + 1));
        }
        for _ in 0..core.k() {
            core.process_block(&[T::ZERO], hist, |r, i| {
                re.push(r);
                im.push(i);
            });
        }
        (re, im)
    }

    #[test]
    fn bank_bit_identical_to_batch_all_lane_counts_and_blocks() {
        let x = gaussian_noise(257, 1.0, 91);
        let k = 19;
        let beta = std::f64::consts::PI / k as f64;
        for count in [1usize, 4, 5, 9] {
            let t = terms(count);
            let (want_re, want_im) = kernel_integral::weighted_bank(&x, k, beta, &t);
            for backend in [Backend::Scalar, Backend::Simd] {
                for block in [1usize, 7, 64, 257] {
                    let mut core = BankCore::new(k, beta, t.clone(), backend);
                    let mut hist = History::default();
                    let (re, im) = stream_bank(&mut core, &mut hist, &x, block);
                    assert_eq!(re, want_re, "re lanes={count} block={block} {backend:?}");
                    assert_eq!(im, want_im, "im lanes={count} block={block} {backend:?}");
                }
            }
        }
    }

    #[test]
    fn f32_bank_bit_identical_to_batch_f32() {
        // the streaming tier of Precision::F32: the generic core at f32
        // must equal the batch generic bank at f32, scalar and SIMD lanes
        let x64 = gaussian_noise(230, 1.0, 92);
        let x: Vec<f32> = x64.iter().map(|&v| v as f32).collect();
        let k = 17;
        let beta = std::f64::consts::PI / k as f64;
        for count in [1usize, 8, 9] {
            let t = terms(count);
            let (want_re, want_im) = kernel_integral::weighted_bank(&x, k, beta, &t);
            for backend in [Backend::Scalar, Backend::Simd] {
                for block in [1usize, 7, 230] {
                    let mut core = BankCore::<f32>::new(k, beta, t.clone(), backend);
                    let mut hist = History::default();
                    let (re, im) = stream_bank(&mut core, &mut hist, &x, block);
                    assert_eq!(re, want_re, "re lanes={count} block={block} {backend:?}");
                    assert_eq!(im, want_im, "im lanes={count} block={block} {backend:?}");
                }
            }
        }
    }

    #[test]
    fn short_and_edge_length_streams_match_batch() {
        let k = 12;
        let beta = std::f64::consts::PI / k as f64;
        let t = terms(3);
        // empty, shorter than K, exactly K, K+1
        for n in [0usize, 5, 12, 13] {
            let x = gaussian_noise(n, 1.0, n as u64 + 7);
            let (want_re, want_im) = kernel_integral::weighted_bank(&x, k, beta, &t);
            let mut core = BankCore::new(k, beta, t.clone(), Backend::Scalar);
            let mut hist = History::default();
            let (re, im) = stream_bank(&mut core, &mut hist, &x, 3);
            assert_eq!(re.len(), n, "n={n}");
            assert_eq!(re, want_re, "re n={n}");
            assert_eq!(im, want_im, "im n={n}");
        }
    }

    #[test]
    fn reset_reproduces_the_first_run_exactly() {
        let x = gaussian_noise(140, 1.0, 3);
        let k = 9;
        let beta = std::f64::consts::PI / k as f64;
        let mut core = BankCore::new(k, beta, terms(5), Backend::Simd);
        let mut hist = History::default();
        let first = stream_bank(&mut core, &mut hist, &x, 16);
        core.reset();
        hist.reset();
        let second = stream_bank(&mut core, &mut hist, &x, 41);
        assert_eq!(first, second);
    }

    #[test]
    fn history_compacts_but_keeps_the_live_window() {
        let mut h = History::default();
        let xs: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        for chunk in xs.chunks(37) {
            h.extend(chunk);
        }
        h.compact(900);
        assert_eq!(h.get(899 + 1), 900.0);
        assert_eq!(h.get(999), 999.0);
        assert_eq!(h.get(-5), 0.0);
        assert!(h.buf.len() <= 1000 - 900 + 64);
        h.reset();
        assert_eq!(h.get(-1), 0.0);
    }
}
