//! Single-component streaming processors: the paper's own recursive forms,
//! one (β, p) component at a time.
//!
//! * [`StreamingSft`] — the kernel-integral recurrence (eq. 21), f64 state.
//! * [`StreamingAsft`] — the attenuated variant (eq. 37), the form that is
//!   safe for indefinite runs in f32 (§2.4; [DESIGN.md §6.4](crate::design)).
//!
//! These are the per-component references; the multi-lane throughput path is
//! the fused bank behind [`super::StreamingGaussian`] /
//! [`super::StreamingMorlet`]. Outputs match the batch implementations in
//! the interior and under the K-zero warm-up/flush (the batch zero
//! extension, [DESIGN.md §6.2](crate::design)).

use crate::dsp::Complex;
use crate::sft::kernel_integral::RENORM_EVERY;
use crate::Result;

/// Ring-buffer delay line of fixed length `d`: `push` returns the sample
/// that entered `d` pushes ago (zero-initialized).
#[derive(Clone, Debug)]
struct DelayLine {
    buf: Vec<f64>,
    idx: usize,
}

impl DelayLine {
    fn new(d: usize) -> Self {
        Self {
            buf: vec![0.0; d.max(1)],
            idx: 0,
        }
    }

    #[inline]
    fn push(&mut self, v: f64) -> f64 {
        let out = self.buf[self.idx];
        self.buf[self.idx] = v;
        self.idx += 1;
        if self.idx == self.buf.len() {
            self.idx = 0;
        }
        out
    }

    fn reset(&mut self) {
        self.buf.iter_mut().for_each(|v| *v = 0.0);
        self.idx = 0;
    }
}

/// One streaming SFT component c_p − i·s_p at (β, p), kernel-integral
/// recurrence (eq. 21): `u₂ₖ₊₁[n] = u₂ₖ₊₁[n−1] + x[n]e^{iβpn} − x[n−2K−1]e^{iβp(n−2K−1)}`.
///
/// Latency: the component at signal index `n − K` becomes available after
/// pushing sample `n` (the window `[n−2K, n]` is centred at `n − K`).
#[derive(Clone, Debug)]
pub struct StreamingSft {
    k: usize,
    /// β·p, kept so [`StreamingSft::reset`] can re-seed the modulators.
    theta: f64,
    /// e^{iβp}
    rot: Complex<f64>,
    /// e^{iβp·n} running modulator
    mod_new: Complex<f64>,
    /// e^{iβp·(n−2K−1)} running modulator for the leaving sample
    mod_old: Complex<f64>,
    /// windowed kernel integral u_{(2K+1)}
    u: Complex<f64>,
    /// e^{-iβp·(n−K)} demodulator for the output point
    demod: Complex<f64>,
    delay: DelayLine,
    pushed: usize,
    /// renormalization counter (long-run modulus drift control; see
    /// [DESIGN.md §6.3](crate::design))
    renorm: usize,
}

impl StreamingSft {
    /// One component processor at window half-width `k`, frequency `beta·p`.
    pub fn new(k: usize, beta: f64, p: f64) -> Result<Self> {
        anyhow::ensure!(k >= 1, "K must be >= 1");
        let th = beta * p;
        Ok(Self {
            k,
            theta: th,
            rot: Complex::cis(th),
            mod_new: Complex::one(),
            // first leaving sample has index −(2K+1): e^{iβp·(−2K−1)}
            mod_old: Complex::cis(-th * (2 * k + 1) as f64),
            u: Complex::zero(),
            // first output is at signal index 0 ⇒ demod starts at e^{0} = 1
            demod: Complex::one(),
            delay: DelayLine::new(2 * k + 1),
            pushed: 0,
            renorm: 0,
        })
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; returns `(c, s)` for signal index `pushed − 1 − K`
    /// once enough samples have arrived (`None` during the first K pushes).
    pub fn push(&mut self, x: f64) -> Option<(f64, f64)> {
        let x_old = self.delay.push(x);
        self.u += self.mod_new.scale(x) - self.mod_old.scale(x_old);
        self.mod_new = self.mod_new * self.rot;
        self.mod_old = self.mod_old * self.rot;
        self.pushed += 1;

        // Unit-circle renormalization on the shared cadence
        // ([`RENORM_EVERY`], the same constant the batch rotors use): the
        // rotators are products of cis() values, so their modulus drifts at
        // ~ε per step — see DESIGN.md §6.3 for the bound.
        self.renorm += 1;
        if self.renorm == RENORM_EVERY {
            self.renorm = 0;
            for m in [&mut self.mod_new, &mut self.mod_old, &mut self.demod] {
                let n = m.norm();
                if n > 0.0 {
                    *m = m.scale(1.0 / n);
                }
            }
        }

        if self.pushed <= self.k {
            return None;
        }
        // eq. 20: c − i·s = e^{-iβp(n−K)}·u at window centre n−K
        let v = self.demod * self.u;
        self.demod = self.demod * self.rot.conj();
        Some((v.re, -v.im))
    }

    /// Push a whole block, appending every ready `(c, s)` pair to `out`
    /// (cleared first). Sample-for-sample identical to calling
    /// [`StreamingSft::push`] in a loop.
    pub fn push_block_into(&mut self, xs: &[f64], out: &mut Vec<(f64, f64)>) {
        out.clear();
        out.extend(xs.iter().filter_map(|&x| self.push(x)));
    }

    /// Flush the tail: push K zeros so the final K outputs emerge. Leaves
    /// the processor spent — [`StreamingSft::reset`] rewinds it for reuse.
    pub fn finish(&mut self) -> Vec<(f64, f64)> {
        (0..self.k).filter_map(|_| self.push(0.0)).collect()
    }

    /// Rewind to a fresh stream without reallocating the delay line.
    pub fn reset(&mut self) {
        self.mod_new = Complex::one();
        self.mod_old = Complex::cis(-self.theta * (2 * self.k + 1) as f64);
        self.u = Complex::zero();
        self.demod = Complex::one();
        self.delay.reset();
        self.pushed = 0;
        self.renorm = 0;
    }
}

/// Streaming ASFT component (eq. 37):
/// `ṽ₂ₖ[n] = e^{−α−iβp}·ṽ₂ₖ[n−1] + x[n] − e^{−2αK}x[n−2K]`,
/// recombined as in [`crate::sft::asft::components_r1`] (the crate's
/// `e^{−αk}`-weight convention: `c̃ − i·s̃ = (−1)^p e^{+αK}(ṽ₂ₖ[m+K] +
/// e^{−2αK}x[m−K])`). Bounded state for α > 0 — this is the variant meant
/// for indefinite runs on f32 hardware ([DESIGN.md §6.4](crate::design)).
#[derive(Clone, Debug)]
pub struct StreamingAsft {
    k: usize,
    p: usize,
    alpha: f64,
    /// e^{−α−iβp}
    decay_rot: Complex<f64>,
    /// e^{−2αK}
    edge: f64,
    v: Complex<f64>,
    delay_2k: DelayLine,
    pushed: usize,
}

impl StreamingAsft {
    /// One attenuated component processor at (K, p, α).
    pub fn new(k: usize, p: usize, alpha: f64) -> Result<Self> {
        anyhow::ensure!(k >= 1, "K must be >= 1");
        anyhow::ensure!(alpha >= 0.0, "alpha must be >= 0");
        let beta = std::f64::consts::PI / k as f64;
        Ok(Self {
            k,
            p,
            alpha,
            decay_rot: Complex::cis(-(beta * p as f64)).scale((-alpha).exp()),
            edge: (-2.0 * alpha * k as f64).exp(),
            v: Complex::zero(),
            delay_2k: DelayLine::new(2 * k),
            pushed: 0,
        })
    }

    /// Fixed output latency in samples.
    pub fn latency(&self) -> usize {
        self.k
    }

    /// Push one sample; yields `(c̃, s̃)` at index `pushed − 1 − K`.
    pub fn push(&mut self, x: f64) -> Option<(f64, f64)> {
        // x[t−2K] serves both the truncated recurrence and, at output time
        // (window centre m = t−K), the x[m−K] recombination term.
        let x_2k = self.delay_2k.push(x);
        self.v = self.decay_rot * self.v + Complex::new(x - self.edge * x_2k, 0.0);
        self.pushed += 1;
        if self.pushed <= self.k {
            return None;
        }
        let sign = if self.p % 2 == 0 { 1.0 } else { -1.0 };
        let w = sign * (self.alpha * self.k as f64).exp();
        let val = (self.v + Complex::new(self.edge * x_2k, 0.0)).scale(w);
        Some((val.re, -val.im))
    }

    /// Push a whole block, appending every ready `(c̃, s̃)` pair to `out`
    /// (cleared first). Sample-for-sample identical to calling
    /// [`StreamingAsft::push`] in a loop.
    pub fn push_block_into(&mut self, xs: &[f64], out: &mut Vec<(f64, f64)>) {
        out.clear();
        out.extend(xs.iter().filter_map(|&x| self.push(x)));
    }

    /// Flush the tail: push K zeros so the final K outputs emerge. Leaves
    /// the processor spent — [`StreamingAsft::reset`] rewinds it for reuse.
    pub fn finish(&mut self) -> Vec<(f64, f64)> {
        (0..self.k).filter_map(|_| self.push(0.0)).collect()
    }

    /// Rewind to a fresh stream without reallocating the delay line.
    pub fn reset(&mut self) {
        self.v = Complex::zero();
        self.delay_2k.reset();
        self.pushed = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::Rng64;
    use crate::sft::{self, Algorithm};

    fn stream_all_sft(s: &mut StreamingSft, x: &[f64]) -> Vec<(f64, f64)> {
        let mut out: Vec<(f64, f64)> = x.iter().filter_map(|&v| s.push(v)).collect();
        out.extend(s.finish());
        out
    }

    #[test]
    fn streaming_sft_matches_batch() {
        let mut rng = Rng64::new(42);
        for &(k, p) in &[(8usize, 0usize), (12, 3), (20, 7), (16, 16)] {
            let n = 160;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let beta = std::f64::consts::PI / k as f64;
            let want = sft::components(Algorithm::Direct, &x, k, beta, p as f64);
            let mut s = StreamingSft::new(k, beta, p as f64).unwrap();
            let got = stream_all_sft(&mut s, &x);
            assert_eq!(got.len(), n);
            for i in 0..n {
                assert!(
                    (got[i].0 - want.c[i]).abs() < 1e-9,
                    "c k={k} p={p} i={i}: {} vs {}",
                    got[i].0,
                    want.c[i]
                );
                assert!(
                    (got[i].1 - want.s[i]).abs() < 1e-9,
                    "s k={k} p={p} i={i}"
                );
            }
        }
    }

    #[test]
    fn streaming_asft_matches_batch() {
        let mut rng = Rng64::new(7);
        for &(k, p, alpha) in &[(8usize, 2usize, 0.01), (16, 5, 0.004), (10, 0, 0.0)] {
            let n = 140;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let beta = std::f64::consts::PI / k as f64;
            let want = sft::direct::asft_components(&x, k, beta, p as f64, alpha);
            let mut s = StreamingAsft::new(k, p, alpha).unwrap();
            let mut got: Vec<(f64, f64)> = x.iter().filter_map(|&v| s.push(v)).collect();
            got.extend(s.finish());
            assert_eq!(got.len(), n);
            for i in 0..n {
                assert!(
                    (got[i].0 - want.c[i]).abs() < 1e-8,
                    "c k={k} p={p} i={i}: {} vs {}",
                    got[i].0,
                    want.c[i]
                );
                assert!((got[i].1 - want.s[i]).abs() < 1e-8, "s k={k} p={p} i={i}");
            }
        }
    }

    #[test]
    fn block_push_matches_sample_push_exactly() {
        let mut rng = Rng64::new(11);
        let x: Vec<f64> = (0..200).map(|_| rng.normal()).collect();
        let beta = std::f64::consts::PI / 10.0;

        let mut sample = StreamingSft::new(10, beta, 3.0).unwrap();
        let want = stream_all_sft(&mut sample, &x);

        let mut block = StreamingSft::new(10, beta, 3.0).unwrap();
        let mut got = Vec::new();
        let mut buf = Vec::new();
        for chunk in x.chunks(17) {
            block.push_block_into(chunk, &mut buf);
            got.extend_from_slice(&buf);
        }
        got.extend(block.finish());
        assert_eq!(got, want);

        let mut sample = StreamingAsft::new(9, 2, 0.01).unwrap();
        let mut want: Vec<(f64, f64)> = x.iter().filter_map(|&v| sample.push(v)).collect();
        want.extend(sample.finish());
        let mut block = StreamingAsft::new(9, 2, 0.01).unwrap();
        let mut got = Vec::new();
        for chunk in x.chunks(23) {
            block.push_block_into(chunk, &mut buf);
            got.extend_from_slice(&buf);
        }
        got.extend(block.finish());
        assert_eq!(got, want);
    }

    #[test]
    fn reset_reproduces_the_first_run() {
        let mut rng = Rng64::new(5);
        let x: Vec<f64> = (0..150).map(|_| rng.normal()).collect();
        let beta = std::f64::consts::PI / 8.0;
        let mut s = StreamingSft::new(8, beta, 2.0).unwrap();
        let first = stream_all_sft(&mut s, &x);
        s.reset();
        let second = stream_all_sft(&mut s, &x);
        assert_eq!(first, second);

        let mut a = StreamingAsft::new(8, 1, 0.02).unwrap();
        let mut first: Vec<(f64, f64)> = x.iter().filter_map(|&v| a.push(v)).collect();
        first.extend(a.finish());
        a.reset();
        let mut second: Vec<(f64, f64)> = x.iter().filter_map(|&v| a.push(v)).collect();
        second.extend(a.finish());
        assert_eq!(first, second);
    }

    #[test]
    fn long_run_phase_stability() {
        // 1M samples: the renormalized rotators must not drift. Compare a
        // late window against a fresh batch computation of the same window.
        let k = 16;
        let beta = std::f64::consts::PI / k as f64;
        let p = 3.0;
        let n = 1_000_000usize;
        let mut rng = Rng64::new(99);
        let mut s = StreamingSft::new(k, beta, p).unwrap();
        let mut window = std::collections::VecDeque::with_capacity(4 * k + 1);
        let mut last = (0.0, 0.0);
        let mut x_hist: Vec<f64> = Vec::with_capacity(4 * k + 1);
        for i in 0..n {
            let v = rng.normal();
            window.push_back(v);
            if window.len() > 4 * k + 1 {
                window.pop_front();
            }
            if let Some(out) = s.push(v) {
                last = out;
            }
            if i == n - 1 {
                x_hist = window.iter().copied().collect();
            }
        }
        // batch recompute: centre of the last full window is index −1−K
        // relative to the end of the stream; with hist length 4K+1 the
        // output index maps to hist position (4K+1) − 1 − K = 3K
        let m = x_hist.len();
        let centre = m - 1 - k;
        let mut want_c = 0.0;
        let mut want_s = 0.0;
        for (j, &v) in x_hist.iter().enumerate() {
            let kk = centre as f64 - j as f64; // x[n−k] convention
            if kk.abs() <= k as f64 {
                want_c += v * (beta * p * kk).cos();
                want_s += v * (beta * p * kk).sin();
            }
        }
        assert!(
            (last.0 - want_c).abs() < 1e-6,
            "c drift after 1M samples: {} vs {}",
            last.0,
            want_c
        );
        assert!((last.1 - want_s).abs() < 1e-6, "s drift after 1M samples");
    }
}
