//! Parallel cost model of the paper's §5.2 analysis — the substitution for
//! the RTX 3090 testbed (see [DESIGN.md §2](crate::design)).
//!
//! The paper's own speed discussion *is* a step-count model: with M cores,
//! the truncated convolution costs `O(Nσ/M)` multiply steps plus a
//! `log₂(6σ+1)`-deep parallel reduction, while the proposed kernel-integral
//! SFT costs `O(NP/M)` pointwise steps plus `P·O(log₂K)` sliding-sum steps.
//! We implement exactly that accounting, with per-wave step costs calibrated
//! against the paper's published endpoint (N=102400, σ=8192: 0.545 ms vs
//! 225.4 ms, a 413.6× ratio), then regenerate the full Fig. 8/9 series and
//! check their *shape* (who wins, where the crossover falls).

use crate::slidingsum::doubling_depth;

/// A GPU abstraction: M parallel lanes; each array-wide wave of work costs a
/// fixed per-step time (launch + memory) plus per-lane-wave compute.
#[derive(Clone, Debug)]
pub struct GpuModel {
    /// Number of parallel cores (paper: RTX 3090, 10496).
    pub cores: usize,
    /// Cost (ns) of one wave of up-to-`cores` fused multiply-adds, conv path.
    pub conv_wave_ns: f64,
    /// Cost (ns) of one wave on the proposed path (pointwise + sliding-sum).
    pub prop_wave_ns: f64,
}

impl Default for GpuModel {
    fn default() -> Self {
        Self::rtx3090()
    }
}

impl GpuModel {
    /// Constants calibrated so the Morlet headline lands on the paper's
    /// numbers (see `tests::headline_calibration`).
    pub fn rtx3090() -> Self {
        Self {
            cores: 10496,
            conv_wave_ns: 117.5,
            prop_wave_ns: 234.4,
        }
    }

    #[inline]
    fn waves(&self, work: u64) -> u64 {
        work.div_ceil(self.cores as u64)
    }

    /// Truncated-convolution Gaussian smoothing (GCT3): window 6σ+1 real taps,
    /// parallel-reduction summation (paper ref [27]).
    pub fn conv_gaussian_ns(&self, n: usize, sigma: f64) -> f64 {
        let w = (6.0 * sigma + 1.0) as u64;
        self.conv_ns(n as u64, w, 1)
    }

    /// Truncated-convolution Morlet (MCT3): complex taps = 2 real planes.
    pub fn conv_morlet_ns(&self, n: usize, sigma: f64) -> f64 {
        let w = (6.0 * sigma + 1.0) as u64;
        self.conv_ns(n as u64, w, 2)
    }

    fn conv_ns(&self, n: u64, w: u64, planes: u64) -> f64 {
        // one FMA wave per tap·output, then a level-by-level tree reduction:
        // level i has N·W/2^i partial sums to combine.
        let mut steps = self.waves(planes * n * w);
        let mut level = w;
        while level > 1 {
            level = level.div_ceil(2);
            steps += self.waves(planes * n * level);
        }
        steps as f64 * self.conv_wave_ns
    }

    /// Proposed kernel-integral SFT path with P orders, all orders in a core
    /// (the paper's chosen variant): ~7NP pointwise multiplies + P·depth(L)
    /// sliding-sum waves of N adds.
    pub fn proposed_ns(&self, n: usize, sigma: f64, p: usize) -> f64 {
        let k = (3.0 * sigma).ceil() as usize;
        let l = 2 * k + 1;
        let pointwise = self.waves(7 * n as u64 * p as u64);
        let sliding = p as u64 * doubling_depth(l) as u64 * self.waves(n as u64);
        (pointwise + sliding) as f64 * self.prop_wave_ns
    }

    /// Proposed Gaussian smoothing (GDP6 default, P = 6).
    pub fn proposed_gaussian_ns(&self, n: usize, sigma: f64) -> f64 {
        self.proposed_ns(n, sigma, 6)
    }

    /// Proposed Morlet direct (MDP6): P_D = 6 orders, cos+sin banks → the
    /// combine is part of the 7NP pointwise budget, complex demod doubles it.
    pub fn proposed_morlet_ns(&self, n: usize, sigma: f64) -> f64 {
        // 1.5× the Gaussian path: two output planes, shared sliding sums.
        1.5 * self.proposed_ns(n, sigma, 6)
    }

    /// Paper-reported speedup of the proposed Morlet over MCT3 at (N, σ).
    pub fn morlet_speedup(&self, n: usize, sigma: f64) -> f64 {
        self.conv_morlet_ns(n, sigma) / self.proposed_morlet_ns(n, sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_calibration() {
        // Paper: N=102400, σ=8192 → proposed 0.545 ms, 413.6× faster.
        let m = GpuModel::rtx3090();
        let prop_ms = m.proposed_morlet_ns(102400, 8192.0) / 1e6;
        let conv_ms = m.conv_morlet_ns(102400, 8192.0) / 1e6;
        assert!(
            (prop_ms - 0.545).abs() / 0.545 < 0.15,
            "proposed {prop_ms} ms vs paper 0.545 ms"
        );
        let ratio = conv_ms / prop_ms;
        assert!(
            (ratio - 413.6).abs() / 413.6 < 0.25,
            "speedup {ratio} vs paper 413.6"
        );
    }

    #[test]
    fn proposed_time_is_log_in_sigma() {
        let m = GpuModel::rtx3090();
        let t1 = m.proposed_gaussian_ns(102400, 16.0);
        let t2 = m.proposed_gaussian_ns(102400, 8192.0);
        // σ ×512 → time grows by a small factor (log), not ×512
        assert!(t2 / t1 < 4.0, "{}", t2 / t1);
        assert!(t2 > t1);
    }

    #[test]
    fn conv_time_is_linear_in_sigma() {
        let m = GpuModel::rtx3090();
        let t1 = m.conv_gaussian_ns(102400, 64.0);
        let t2 = m.conv_gaussian_ns(102400, 128.0);
        let ratio = t2 / t1;
        assert!((ratio - 2.0).abs() < 0.3, "{ratio}");
    }

    #[test]
    fn proposed_time_independent_of_n_below_cores() {
        // with N ≤ M the wave counts stop depending on N
        let m = GpuModel::rtx3090();
        let t1 = m.proposed_gaussian_ns(1490, 16.0);
        let t2 = m.proposed_gaussian_ns(100, 16.0);
        assert!((t1 / t2 - 1.0).abs() < 0.35, "{} vs {}", t1, t2);
    }

    #[test]
    fn crossover_exists_at_small_sigma_and_n() {
        // paper Figs. 8(b)/9(b): conv slightly faster only when both N and σ
        // are small; proposed wins for large σ at fixed N=102400.
        let m = GpuModel::rtx3090();
        assert!(m.conv_morlet_ns(100, 16.0) < m.proposed_morlet_ns(100, 16.0));
        assert!(m.conv_morlet_ns(102400, 8192.0) > m.proposed_morlet_ns(102400, 8192.0));
    }

    #[test]
    fn speedup_grows_with_sigma() {
        let m = GpuModel::rtx3090();
        let s16 = m.morlet_speedup(102400, 16.0);
        let s8192 = m.morlet_speedup(102400, 8192.0);
        assert!(s8192 > 50.0 * s16.max(0.02), "s16={s16} s8192={s8192}");
    }
}
