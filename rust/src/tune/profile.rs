//! The on-disk tuning profile: a std-only, line-oriented text format.
//!
//! One file holds every calibrated decision for a host. The format is
//! deliberately boring so it survives hand edits, partial writes, and
//! foreign tools ([DESIGN.md §11](crate::design)):
//!
//! ```text
//! masft-tune-profile v1
//! # optional comments
//! decide workload=gaussian_smooth n=65536 k=128 backend=simd precision=f64 par=auto ns_per_elem=0.82
//! ```
//!
//! Parsing is corruption-tolerant: the header line must match exactly
//! (a version bump rejects the whole file — decisions do not migrate
//! across format versions), but *within* the body every malformed line,
//! unknown enum value, or unknown key is skipped/ignored with a counted
//! warning instead of failing the load. [`Profile::store`] merges with
//! whatever is already on disk, so repeated partial calibrations
//! accumulate instead of clobbering each other.

use std::collections::BTreeMap;
use std::path::Path;

use crate::exec::Parallelism;
use crate::plan::{Backend, Precision};
use crate::Result;

/// Format version accepted by [`Profile::parse`]. Bumping it invalidates
/// every profile on disk by design: decisions are only meaningful against
/// the candidate grid and legality table they were measured under.
pub const FORMAT_VERSION: u32 = 1;

/// Exact first-line header a profile file must carry.
pub const HEADER: &str = "masft-tune-profile v1";

/// The workload families the calibrator distinguishes. Each maps onto one
/// plan surface; [`crate::tune::resolve_gaussian`] and friends pick the
/// matching family when looking decisions up.
#[derive(Copy, Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Workload {
    /// Gaussian smoothing ([`crate::plan::Derivative::Smooth`]).
    GaussianSmooth,
    /// First Gaussian differential.
    GaussianD1,
    /// Second Gaussian differential.
    GaussianD2,
    /// Single-σ Morlet transform (direct-SFT bank).
    Morlet,
    /// Multi-scale CWT (one Morlet row per σ).
    Scalogram,
    /// Oriented 2-D Gabor bank (separable passes).
    Gabor2d,
}

impl Workload {
    /// Stable token used in profile files.
    pub fn as_str(self) -> &'static str {
        match self {
            Workload::GaussianSmooth => "gaussian_smooth",
            Workload::GaussianD1 => "gaussian_d1",
            Workload::GaussianD2 => "gaussian_d2",
            Workload::Morlet => "morlet",
            Workload::Scalogram => "scalogram",
            Workload::Gabor2d => "gabor2d",
        }
    }

    fn from_str(s: &str) -> Option<Workload> {
        Some(match s {
            "gaussian_smooth" => Workload::GaussianSmooth,
            "gaussian_d1" => Workload::GaussianD1,
            "gaussian_d2" => Workload::GaussianD2,
            "morlet" => Workload::Morlet,
            "scalogram" => Workload::Scalogram,
            "gabor2d" => Workload::Gabor2d,
            _ => return None,
        })
    }
}

/// Round a shape dimension into its profile bucket (next power of two).
/// Buckets keep the decision table small and make lookups exact: the
/// calibrator measures at bucket boundaries and resolution buckets the
/// query the same way.
pub fn bucket(v: usize) -> u32 {
    let v = v.clamp(1, 1 << 30);
    v.next_power_of_two() as u32
}

/// One calibrated decision: the fastest legal configuration measured for a
/// (workload, N-bucket, K-bucket) cell.
#[derive(Clone, Debug, PartialEq)]
pub struct Decision {
    /// Workload family the measurement ran on.
    pub workload: Workload,
    /// Signal-length bucket (power of two).
    pub n: u32,
    /// Window half-width bucket (power of two).
    pub k: u32,
    /// Winning backend — always an in-process backend; the calibrator never
    /// proposes [`Backend::Runtime`] (and the parser rejects it).
    pub backend: Backend,
    /// Winning precision tier.
    pub precision: Precision,
    /// Winning worker fan-out (only meaningful for row-parallel workloads;
    /// `par=auto` means "leave the exec-layer adaptive fan-out in charge").
    pub parallelism: Parallelism,
    /// Measured cost of the winner, nanoseconds per output element.
    pub ns_per_elem: f64,
}

impl Decision {
    /// The decision's one-line profile-file form (`decide workload=… …`).
    pub fn render(&self) -> String {
        let par = match self.parallelism {
            Parallelism::Sequential => "seq".to_string(),
            Parallelism::Auto => "auto".to_string(),
            Parallelism::Threads(n) => format!("threads:{n}"),
        };
        let backend = match self.backend {
            Backend::PureRust => "scalar",
            Backend::Simd => "simd",
            // never written by the calibrator; renders defensively so a
            // hand-assembled Decision still round-trips as a parse warning
            Backend::Runtime | Backend::Auto => "invalid",
        };
        let precision = match self.precision {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
            Precision::Auto => "invalid",
        };
        format!(
            "decide workload={} n={} k={} backend={} precision={} par={} ns_per_elem={}",
            self.workload.as_str(),
            self.n,
            self.k,
            backend,
            precision,
            par,
            self.ns_per_elem
        )
    }
}

/// Profile key: ordered so all N-buckets of one (workload, K-bucket) cell
/// are contiguous and ascending — [`Profile::lookup`] takes the last.
type Key = (Workload, u32, u32); // (workload, k bucket, n bucket)

/// A parsed (or freshly calibrated) set of tuning decisions.
///
/// Deterministic by construction: decisions live in a [`BTreeMap`], so
/// [`Profile::serialize`] is byte-stable for equal decision sets —
/// `rust/tests/tune_profile.rs` pins this.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Profile {
    decisions: BTreeMap<Key, Decision>,
    /// Malformed lines / unknown tokens tolerated while parsing.
    pub warnings: u64,
}

impl Profile {
    /// Empty profile (resolution over it always falls back to heuristics).
    pub fn new() -> Profile {
        Profile::default()
    }

    /// Number of decisions held.
    pub fn len(&self) -> usize {
        self.decisions.len()
    }

    /// True when no decisions are held.
    pub fn is_empty(&self) -> bool {
        self.decisions.is_empty()
    }

    /// Insert (or replace) a decision at its (workload, K, N) cell.
    pub fn insert(&mut self, d: Decision) {
        self.decisions.insert((d.workload, d.k, d.n), d);
    }

    /// Iterate decisions in key order.
    pub fn decisions(&self) -> impl Iterator<Item = &Decision> {
        self.decisions.values()
    }

    /// The decision for `workload` at window half-width `k`, if calibrated.
    ///
    /// Lookup buckets `k` exactly; among the N-buckets measured for that
    /// cell it returns the **largest** — plan-time resolution is
    /// length-agnostic, and the large-N rows are the ones that dominate
    /// serving cost ([DESIGN.md §11](crate::design)).
    pub fn lookup(&self, workload: Workload, k: usize) -> Option<&Decision> {
        let kb = bucket(k);
        self.decisions
            .range((workload, kb, 0)..=(workload, kb, u32::MAX))
            .next_back()
            .map(|(_, d)| d)
    }

    /// Parse a profile file body.
    ///
    /// Fails only when the version header is missing or names another
    /// format version. Every body-level fault — garbage lines, unknown
    /// enum values, missing keys, a truncated final line — is skipped with
    /// [`Profile::warnings`] incremented, never a panic or an error.
    pub fn parse(text: &str) -> Result<Profile> {
        let mut lines = text.lines();
        let header = loop {
            match lines.next() {
                Some(l) => {
                    let t = l.trim();
                    if t.is_empty() || t.starts_with('#') {
                        continue;
                    }
                    break t;
                }
                None => anyhow::bail!("tuning profile is empty (missing `{HEADER}` header)"),
            }
        };
        anyhow::ensure!(
            header == HEADER,
            "tuning profile header {header:?} does not match `{HEADER}`; \
             refusing to reuse decisions across format versions"
        );
        let mut p = Profile::new();
        for line in lines {
            let t = line.trim();
            if t.is_empty() || t.starts_with('#') {
                continue;
            }
            match parse_decision(t) {
                Ok((d, warned)) => {
                    p.warnings += warned;
                    p.insert(d);
                }
                Err(_) => p.warnings += 1,
            }
        }
        Ok(p)
    }

    /// Render the whole profile (header + sorted decision lines).
    pub fn serialize(&self) -> String {
        let mut out = String::from(HEADER);
        out.push('\n');
        for d in self.decisions.values() {
            out.push_str(&d.render());
            out.push('\n');
        }
        out
    }

    /// Read and parse a profile file.
    pub fn load(path: &Path) -> Result<Profile> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading tuning profile {}: {e}", path.display()))?;
        Profile::parse(&text)
    }

    /// Write the profile to `path`, **merging** with any readable profile
    /// already there: decisions present on disk but not in `self` are kept,
    /// cells measured in both are replaced by `self`'s. An unreadable or
    /// version-mismatched existing file is overwritten (its decisions are
    /// untrustworthy by definition). The write goes through a temp file +
    /// rename so a crash never leaves a half-written profile.
    pub fn store(&self, path: &Path) -> Result<()> {
        let mut merged = Profile::load(path).unwrap_or_default();
        merged.warnings = 0;
        for d in self.decisions.values() {
            merged.insert(d.clone());
        }
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, merged.serialize())
            .map_err(|e| anyhow::anyhow!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| anyhow::anyhow!("renaming {} -> {}: {e}", tmp.display(), path.display()))?;
        Ok(())
    }
}

/// Parse one `decide …` line; returns the decision plus the count of
/// unknown-key warnings it raised. Errors describe why the line is unusable
/// (the caller downgrades them to a counted warning).
fn parse_decision(line: &str) -> std::result::Result<(Decision, u64), String> {
    let mut tokens = line.split_whitespace();
    let tag = tokens.next().ok_or("empty line")?;
    if tag != "decide" {
        return Err(format!("unknown line tag {tag:?}"));
    }
    let mut workload = None;
    let mut n = None;
    let mut k = None;
    let mut backend = None;
    let mut precision = None;
    let mut parallelism = None;
    let mut ns_per_elem = 0.0f64;
    let mut warnings = 0u64;
    for tok in tokens {
        let (key, val) = tok.split_once('=').ok_or_else(|| format!("bare token {tok:?}"))?;
        match key {
            "workload" => {
                workload =
                    Some(Workload::from_str(val).ok_or_else(|| format!("workload {val:?}"))?)
            }
            "n" => n = Some(val.parse::<u32>().map_err(|e| e.to_string())?),
            "k" => k = Some(val.parse::<u32>().map_err(|e| e.to_string())?),
            "backend" => {
                backend = Some(match val {
                    "scalar" => Backend::PureRust,
                    "simd" => Backend::Simd,
                    other => return Err(format!("backend {other:?}")),
                })
            }
            "precision" => {
                precision = Some(match val {
                    "f64" => Precision::F64,
                    "f32" => Precision::F32,
                    other => return Err(format!("precision {other:?}")),
                })
            }
            "par" => {
                parallelism = Some(match val {
                    "seq" => Parallelism::Sequential,
                    "auto" => Parallelism::Auto,
                    other => match other.strip_prefix("threads:") {
                        Some(c) => {
                            Parallelism::Threads(c.parse().map_err(|_| format!("par {val:?}"))?)
                        }
                        None => return Err(format!("par {val:?}")),
                    },
                })
            }
            "ns_per_elem" => ns_per_elem = val.parse().map_err(|_| format!("ns {val:?}"))?,
            // forward compatibility: later minor revisions may add keys;
            // they are tolerated but surfaced in the warning count
            _ => warnings += 1,
        }
    }
    let d = Decision {
        workload: workload.ok_or("missing workload")?,
        n: n.ok_or("missing n")?,
        k: k.ok_or("missing k")?,
        backend: backend.ok_or("missing backend")?,
        precision: precision.ok_or("missing precision")?,
        parallelism: parallelism.ok_or("missing par")?,
        ns_per_elem,
    };
    Ok((d, warnings))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(w: Workload, n: u32, k: u32) -> Decision {
        Decision {
            workload: w,
            n,
            k,
            backend: Backend::Simd,
            precision: Precision::F64,
            parallelism: Parallelism::Auto,
            ns_per_elem: 1.5,
        }
    }

    #[test]
    fn lookup_prefers_largest_n_bucket() {
        let mut p = Profile::new();
        p.insert(Decision {
            backend: Backend::PureRust,
            ..d(Workload::Morlet, 4096, 128)
        });
        p.insert(d(Workload::Morlet, 65536, 128));
        let hit = p.lookup(Workload::Morlet, 100).unwrap();
        assert_eq!(hit.n, 65536);
        assert_eq!(hit.backend, Backend::Simd);
        assert!(p.lookup(Workload::Morlet, 300).is_none());
        assert!(p.lookup(Workload::Scalogram, 100).is_none());
    }

    #[test]
    fn bucket_is_next_power_of_two() {
        assert_eq!(bucket(0), 1);
        assert_eq!(bucket(1), 1);
        assert_eq!(bucket(100), 128);
        assert_eq!(bucket(128), 128);
        assert_eq!(bucket(129), 256);
    }

    #[test]
    fn header_matches_format_version() {
        assert!(HEADER.ends_with(&format!("v{FORMAT_VERSION}")));
    }
}
