//! The calibration pass: micro-benchmark every legal candidate on a grid
//! of (workload, N, K) shapes and keep the fastest per cell.
//!
//! The pass is deterministic modulo the injected [`Measurer`]: shape grid,
//! candidate order, input signal, and tie-breaking are all fixed, so a
//! deterministic measurer yields a byte-stable [`Profile`]
//! (`rust/tests/tune_profile.rs` pins this). Only legal candidates are ever
//! measured — the spec layer's rejections ([`Backend::Runtime`]×F32,
//! non-direct-SFT F32 Morlet) cannot be "won" into a profile.

use crate::exec::Parallelism;
use crate::morlet::Method;
use crate::plan::{
    Derivative, GaussianSpec, MorletSpec, Plan, Precision, ScalogramSpec, Scratch,
};
use crate::plan::Backend;
use crate::Result;

use super::measure::{Candidate, Measurer};
use super::profile::{bucket, Decision, Profile, Workload};

/// Calibration grid selection.
#[derive(Clone, Debug, Default)]
pub struct CalibrateOptions {
    /// Smaller grid and shapes (`masft calibrate --quick`, CI smoke).
    pub quick: bool,
}

impl CalibrateOptions {
    fn lengths(&self) -> &'static [usize] {
        if self.quick {
            &[4096, 32768]
        } else {
            &[4096, 16384, 65536, 262144]
        }
    }

    fn windows(&self) -> &'static [usize] {
        if self.quick {
            &[16, 128]
        } else {
            &[16, 64, 256, 1024]
        }
    }
}

/// Deterministic calibration input: a bounded, structured signal (pure
/// noise under-exercises the bank's accumulation paths; a constant
/// over-exercises dead flops). No RNG — calibration must not depend on
/// process entropy.
fn signal(n: usize) -> Vec<f64> {
    (0..n)
        .map(|i| {
            let t = i as f64;
            (0.05 * t).sin() + 0.25 * (0.011 * t).cos()
        })
        .collect()
}

/// In-process backends the calibrator races. [`Backend::Runtime`] is never
/// a candidate: it defines its own serving numerics and the resolver must
/// not switch a caller onto it silently.
const BACKENDS: [Backend; 2] = [Backend::PureRust, Backend::Simd];

const PRECISIONS: [Precision; 2] = [Precision::F64, Precision::F32];

/// Run the full calibration under `measurer`, returning the winning
/// decision per grid cell. Plans are built through the normal entry points,
/// so fits are shared with (and warm) the process-wide plan cache.
pub fn calibrate(measurer: &mut dyn Measurer, opts: &CalibrateOptions) -> Result<Profile> {
    let mut profile = Profile::new();
    for &n in opts.lengths() {
        let x = signal(n);
        for &k in opts.windows() {
            let sigma = k as f64 / 3.0;
            for workload in [
                Workload::GaussianSmooth,
                Workload::GaussianD1,
                Workload::GaussianD2,
            ] {
                let derivative = match workload {
                    Workload::GaussianD1 => Derivative::First,
                    Workload::GaussianD2 => Derivative::Second,
                    _ => Derivative::Smooth,
                };
                calibrate_cell(measurer, &mut profile, workload, n, k, |b, p| {
                    let spec = GaussianSpec::builder(sigma)
                        .derivative(derivative)
                        .window(k)
                        .backend(b)
                        .precision(p)
                        .build()?;
                    let plan = spec.plan()?;
                    let x = &x;
                    let mut out = Vec::new();
                    let mut scratch = Scratch::default();
                    Ok(Box::new(move || {
                        plan.execute_into(x, &mut out, &mut scratch);
                    }))
                })?;
            }
            calibrate_cell(measurer, &mut profile, Workload::Morlet, n, k, |b, p| {
                let spec = MorletSpec::builder(sigma, 6.0)
                    .method(Method::DirectSft { p_d: 6 })
                    .window(k)
                    .backend(b)
                    .precision(p)
                    .build()?;
                let plan = spec.plan()?;
                let x = &x;
                let mut out = Vec::new();
                let mut scratch = Scratch::default();
                Ok(Box::new(move || {
                    plan.execute_into(x, &mut out, &mut scratch);
                }))
            })?;
            calibrate_scalogram(measurer, &mut profile, n, k, sigma, &x)?;
        }
    }
    Ok(profile)
}

/// Race backend × precision (sequential execution) for one cell and record
/// the winner. `make_run` builds a fresh executable closure per candidate.
fn calibrate_cell<'a, F>(
    measurer: &mut dyn Measurer,
    profile: &mut Profile,
    workload: Workload,
    n: usize,
    k: usize,
    mut make_run: F,
) -> Result<()>
where
    F: FnMut(Backend, Precision) -> Result<Box<dyn FnMut() + 'a>>,
{
    let mut best: Option<(u64, Backend, Precision)> = None;
    for b in BACKENDS {
        for p in PRECISIONS {
            let mut run = make_run(b, p)?;
            let cand = Candidate {
                workload,
                n,
                k,
                backend: b,
                precision: p,
                parallelism: Parallelism::Sequential,
            };
            let ns = measurer.measure(&cand, &mut *run);
            // strict `<` keeps the first-listed candidate on ties, making
            // the winner deterministic under any measurer
            if best.map(|(t, _, _)| ns < t).unwrap_or(true) {
                best = Some((ns, b, p));
            }
        }
    }
    let (ns, backend, precision) = best.expect("candidate grid is never empty");
    profile.insert(Decision {
        workload,
        n: bucket(n),
        k: bucket(k),
        backend,
        precision,
        parallelism: Parallelism::Auto,
        ns_per_elem: ns as f64 / n as f64,
    });
    Ok(())
}

/// The scalogram cell additionally races the row fan-out (Sequential vs
/// the exec-layer adaptive Auto), since rows are the crate's
/// embarrassingly-parallel axis.
fn calibrate_scalogram(
    measurer: &mut dyn Measurer,
    profile: &mut Profile,
    n: usize,
    k: usize,
    sigma: f64,
    x: &[f64],
) -> Result<()> {
    let sigmas = [sigma * 0.25, sigma * 0.5, sigma];
    let mut best: Option<(u64, Backend, Precision, Parallelism)> = None;
    for b in BACKENDS {
        for p in PRECISIONS {
            for par in [Parallelism::Sequential, Parallelism::Auto] {
                let spec = ScalogramSpec::builder(6.0)
                    .sigmas(&sigmas)
                    .parallelism(par)
                    .backend(b)
                    .precision(p)
                    .build()?;
                let plan = spec.plan()?;
                let mut out = crate::morlet::Scalogram::default();
                let mut scratch = Scratch::default();
                let cand = Candidate {
                    workload: Workload::Scalogram,
                    n,
                    k,
                    backend: b,
                    precision: p,
                    parallelism: par,
                };
                let ns = measurer.measure(&cand, &mut || {
                    plan.execute_into(x, &mut out, &mut scratch);
                });
                if best.map(|(t, _, _, _)| ns < t).unwrap_or(true) {
                    best = Some((ns, b, p, par));
                }
            }
        }
    }
    let (ns, backend, precision, parallelism) = best.expect("candidate grid is never empty");
    profile.insert(Decision {
        workload: Workload::Scalogram,
        n: bucket(n),
        k: bucket(k),
        backend,
        precision,
        parallelism,
        ns_per_elem: ns as f64 / (n * sigmas.len()) as f64,
    });
    Ok(())
}
