//! Timing isolation for the calibrator.
//!
//! All wall-clock reads of `masft::tune` live in this file, behind the
//! [`Measurer`] trait: the calibrator is written against the trait, so
//! tests drive it with an injected deterministic cost model and get
//! byte-stable profiles, while `masft calibrate` plugs in [`WallClock`].
//! masft-lint's `no-wall-clock-in-core` allowlist names exactly this file;
//! a clock call anywhere else in `tune/` fails CI.

// Wall-clock reads are this file's job (it is the calibration timer) — the
// workspace-wide clippy `disallowed-methods` ban exists to keep them out of
// the numeric core, not out of here.
#![allow(clippy::disallowed_methods)]

use crate::exec::Parallelism;
use crate::plan::{Backend, Precision};

use super::profile::Workload;

/// One measurement target: a candidate configuration applied to one
/// (workload, N, K) shape. Deterministic measurers may derive their cost
/// from these fields alone without running the closure.
#[derive(Clone, Debug, PartialEq)]
pub struct Candidate {
    /// Workload family being measured.
    pub workload: Workload,
    /// Signal length of the measurement input.
    pub n: usize,
    /// Window half-width of the measured spec.
    pub k: usize,
    /// Backend under test (always concrete).
    pub backend: Backend,
    /// Precision tier under test (always concrete).
    pub precision: Precision,
    /// Worker fan-out under test.
    pub parallelism: Parallelism,
}

/// Times one execution of a candidate. The calibrator calls this once per
/// candidate and trusts the returned figure; repetition/robustness policy
/// belongs to the implementation.
pub trait Measurer {
    /// Nanoseconds one run of `run` costs under this measurer's policy.
    /// Implementations may run the closure any number of times (including
    /// zero, for model-based measurers).
    fn measure(&mut self, candidate: &Candidate, run: &mut dyn FnMut()) -> u64;
}

/// The real measurer: wall-clock timing with warmup, taking the minimum
/// over a few repetitions (minimum is the standard noise-robust statistic
/// for micro-benchmarks — cache and scheduler interference only ever adds
/// time).
#[derive(Clone, Debug)]
pub struct WallClock {
    /// Untimed runs before measuring (warms caches and the plan's scratch).
    pub warmup: u32,
    /// Timed repetitions; the minimum is reported.
    pub reps: u32,
}

impl Default for WallClock {
    fn default() -> Self {
        WallClock { warmup: 1, reps: 3 }
    }
}

impl WallClock {
    /// Reduced-effort configuration for `masft calibrate --quick`.
    pub fn quick() -> WallClock {
        WallClock { warmup: 1, reps: 2 }
    }
}

impl Measurer for WallClock {
    fn measure(&mut self, _candidate: &Candidate, run: &mut dyn FnMut()) -> u64 {
        for _ in 0..self.warmup {
            run();
        }
        let mut best = u64::MAX;
        for _ in 0..self.reps.max(1) {
            let t0 = std::time::Instant::now();
            run();
            best = best.min(t0.elapsed().as_nanos() as u64);
        }
        best.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_runs_the_closure() {
        let mut calls = 0u32;
        let mut m = WallClock { warmup: 2, reps: 3 };
        let c = Candidate {
            workload: Workload::Morlet,
            n: 16,
            k: 4,
            backend: Backend::PureRust,
            precision: Precision::F64,
            parallelism: Parallelism::Sequential,
        };
        let ns = m.measure(&c, &mut || calls += 1);
        assert_eq!(calls, 5);
        assert!(ns >= 1);
    }
}
