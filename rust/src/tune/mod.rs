//! `masft::tune` — measurement-driven autotuning (FFTW-wisdom style).
//!
//! The paper's core observation is that the *right* configuration depends
//! on shape: direct SFT wins at small σ, the kernel-integral/ASFT family
//! at large σ, and the crossover moves with the hardware. This module
//! closes the loop over the crate's knob matrix:
//!
//! 1. **Calibrate** ([`calibrate`]): micro-benchmark every legal
//!    backend × precision (× parallelism) candidate over a grid of
//!    (workload, N, K) shapes on the host — `masft calibrate` on the CLI.
//! 2. **Persist** ([`profile::Profile`]): a std-only, versioned,
//!    corruption-tolerant text file, merged on rewrite.
//! 3. **Resolve**: [`Backend::Auto`] / [`Precision::Auto`] knobs on spec
//!    builders resolve to the fastest *legal* concrete configuration
//!    before any plan (or plan-cache key) is built — profile first, then
//!    the documented shape heuristics.
//!
//! Resolution order is always **Auto → profile → heuristic → default**
//! ([DESIGN.md §11](crate::design)). The heuristics, when no profile row
//! matches:
//!
//! * backend: [`Backend::Simd`] for window half-widths K ≥ 8 (one full
//!   [`crate::simd::F64x4`] lane block), scalar below — both are
//!   bit-identical, so this is purely a speed call;
//! * precision: [`Precision::F64`], the reference tier — a numerics-
//!   changing tier is only auto-selected when a profile *measured* it on
//!   this host (and the spec layer allows it);
//! * parallelism: keep [`Parallelism::Auto`]'s exec-layer adaptive
//!   fan-out (unchanged semantics from `masft::exec`).
//!
//! Correctness comes first: resolution never yields a configuration the
//! spec layer forbids. [`Backend::Runtime`] is never auto-selected (it has
//! its own serving numerics); a spec pinned to Runtime resolves
//! `Precision::Auto` to F64; a non-direct-SFT Morlet resolves
//! `Precision::Auto` to F64. Because Auto is *purely a selector*, an
//! Auto spec and its resolved concrete twin build byte-identical plans and
//! share one plan-cache entry (`rust/tests/auto_parity.rs` pins both).
//!
//! Every resolution is counted (per source and per choice) and surfaced in
//! [`crate::coordinator::Stats`], so profile drift and unexpected
//! fallbacks are visible in serving.

pub mod calibrate;
pub mod measure;
pub mod profile;

pub use calibrate::{calibrate as run_calibration, CalibrateOptions};
pub use measure::{Candidate, Measurer, WallClock};
pub use profile::{Decision, Profile, Workload};

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::exec::Parallelism;
use crate::plan::{
    Backend, Derivative, Gabor2dSpec, GaussianSpec, MorletSpec, Precision, ScalogramSpec,
    TransformSpec,
};
use crate::Result;

// ---------------------------------------------------------------------------
// process-wide profile + resolution counters
// ---------------------------------------------------------------------------

static PROFILE: Mutex<Option<Arc<Profile>>> = Mutex::new(None);

static RESOLUTIONS: AtomicU64 = AtomicU64::new(0);
static FROM_PROFILE: AtomicU64 = AtomicU64::new(0);
static FROM_HEURISTIC: AtomicU64 = AtomicU64::new(0);
static BACKEND_SCALAR: AtomicU64 = AtomicU64::new(0);
static BACKEND_SIMD: AtomicU64 = AtomicU64::new(0);
static PRECISION_F64: AtomicU64 = AtomicU64::new(0);
static PRECISION_F32: AtomicU64 = AtomicU64::new(0);
static PROFILE_WARNINGS: AtomicU64 = AtomicU64::new(0);
static LAST: Mutex<String> = Mutex::new(String::new());

/// Snapshot of the process-wide Auto-resolution counters. Resolution runs
/// in the plan layer (so one profile serves every coordinator, graph, and
/// direct plan in the process); [`crate::coordinator::Coordinator::stats`]
/// embeds this snapshot.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TuneStats {
    /// Specs with at least one Auto knob resolved.
    pub resolutions: u64,
    /// Resolutions decided by an installed profile row.
    pub profile_hits: u64,
    /// Resolutions that fell back to the shape heuristics.
    pub heuristic_fallbacks: u64,
    /// `Backend::Auto` choices that landed on the scalar backend.
    pub backend_scalar: u64,
    /// `Backend::Auto` choices that landed on the SIMD backend.
    pub backend_simd: u64,
    /// `Precision::Auto` choices that landed on the f64 tier.
    pub precision_f64: u64,
    /// `Precision::Auto` choices that landed on the f32 tier.
    pub precision_f32: u64,
    /// Profile load failures plus parse warnings tolerated.
    pub profile_warnings: u64,
    /// Human-readable rendering of the most recent resolution.
    pub last: String,
}

/// Read the current counter values.
pub fn stats() -> TuneStats {
    TuneStats {
        resolutions: RESOLUTIONS.load(Ordering::Relaxed),
        profile_hits: FROM_PROFILE.load(Ordering::Relaxed),
        heuristic_fallbacks: FROM_HEURISTIC.load(Ordering::Relaxed),
        backend_scalar: BACKEND_SCALAR.load(Ordering::Relaxed),
        backend_simd: BACKEND_SIMD.load(Ordering::Relaxed),
        precision_f64: PRECISION_F64.load(Ordering::Relaxed),
        precision_f32: PRECISION_F32.load(Ordering::Relaxed),
        profile_warnings: PROFILE_WARNINGS.load(Ordering::Relaxed),
        last: LAST.lock().unwrap_or_else(|e| e.into_inner()).clone(),
    }
}

/// Install `profile` as the process-wide decision source for subsequent
/// Auto resolutions. Its parse warnings are folded into the warning
/// counter.
pub fn install_profile(profile: Profile) {
    PROFILE_WARNINGS.fetch_add(profile.warnings, Ordering::Relaxed);
    *PROFILE.lock().unwrap_or_else(|e| e.into_inner()) = Some(Arc::new(profile));
}

/// Drop the installed profile (resolutions fall back to heuristics).
/// Primarily test/ops support — e.g. after replacing a stale profile file.
pub fn clear_profile() {
    *PROFILE.lock().unwrap_or_else(|e| e.into_inner()) = None;
}

/// The currently installed profile, if any.
pub fn installed_profile() -> Option<Arc<Profile>> {
    PROFILE.lock().unwrap_or_else(|e| e.into_inner()).clone()
}

/// Load `path` and install it. On any failure — unreadable file, missing
/// header, format-version mismatch — nothing is installed, the warning
/// counter is incremented, and the error is returned; resolution keeps
/// working on heuristics. Never panics.
pub fn load_profile(path: &Path) -> Result<()> {
    match Profile::load(path) {
        Ok(p) => {
            install_profile(p);
            Ok(())
        }
        Err(e) => {
            PROFILE_WARNINGS.fetch_add(1, Ordering::Relaxed);
            Err(e)
        }
    }
}

// ---------------------------------------------------------------------------
// heuristics (the documented no-profile fallback)
// ---------------------------------------------------------------------------

/// Shape heuristic for `Backend::Auto` with no profile row: SIMD once the
/// window spans at least one full [`crate::simd::F64x4`] lane block
/// (K ≥ 8 taps across the ±K window), scalar below. Both backends are
/// bit-identical, so this can only cost speed, never values.
pub fn heuristic_backend(k: usize) -> Backend {
    if k < 8 {
        Backend::PureRust
    } else {
        Backend::Simd
    }
}

/// Heuristic for `Precision::Auto` with no profile row: the f64 reference
/// tier. Auto only moves to a numerics-changing tier on measured evidence.
pub fn heuristic_precision() -> Precision {
    Precision::F64
}

// ---------------------------------------------------------------------------
// resolution
// ---------------------------------------------------------------------------

/// Outcome of resolving one spec's Auto knobs.
struct Choice {
    backend: Backend,
    precision: Precision,
    parallelism: Option<Parallelism>,
}

/// Core per-knob resolution. `f32_legal` is the spec layer's verdict on
/// whether the f32 tier may run this spec (e.g. false for a non-direct-SFT
/// Morlet); an explicit Runtime backend also forces f64, mirroring
/// `check_runtime_precision`.
fn resolve_knobs(
    workload: Workload,
    k: usize,
    backend: Backend,
    precision: Precision,
    parallelism: Option<Parallelism>,
    f32_legal: bool,
) -> Choice {
    let row = installed_profile();
    let row = row.as_ref().and_then(|p| p.lookup(workload, k));
    let backend_auto = backend == Backend::Auto;
    let precision_auto = precision == Precision::Auto;
    let par_auto = parallelism == Some(Parallelism::Auto);

    let chosen_backend = if backend_auto {
        match row {
            Some(d) => d.backend,
            None => heuristic_backend(k),
        }
    } else {
        backend
    };
    let chosen_precision = if precision_auto {
        let want = match row {
            Some(d) => d.precision,
            None => heuristic_precision(),
        };
        // correctness-first legality: never auto-select a tier the spec
        // layer would reject for this configuration
        if want == Precision::F32 && (!f32_legal || chosen_backend == Backend::Runtime) {
            Precision::F64
        } else {
            want
        }
    } else {
        precision
    };
    let chosen_par = match (par_auto, row) {
        // a profile row may pin the fan-out it measured fastest; with no
        // row, Parallelism::Auto keeps its exec-layer adaptive meaning
        (true, Some(d)) => Some(d.parallelism),
        _ => parallelism,
    };

    RESOLUTIONS.fetch_add(1, Ordering::Relaxed);
    if row.is_some() {
        FROM_PROFILE.fetch_add(1, Ordering::Relaxed);
    } else {
        FROM_HEURISTIC.fetch_add(1, Ordering::Relaxed);
    }
    if backend_auto {
        match chosen_backend {
            Backend::Simd => BACKEND_SIMD.fetch_add(1, Ordering::Relaxed),
            _ => BACKEND_SCALAR.fetch_add(1, Ordering::Relaxed),
        };
    }
    if precision_auto {
        match chosen_precision {
            Precision::F32 => PRECISION_F32.fetch_add(1, Ordering::Relaxed),
            _ => PRECISION_F64.fetch_add(1, Ordering::Relaxed),
        };
    }
    *LAST.lock().unwrap_or_else(|e| e.into_inner()) = format!(
        "{} k={} -> backend={:?} precision={:?} ({})",
        workload.as_str(),
        k,
        chosen_backend,
        chosen_precision,
        if row.is_some() { "profile" } else { "heuristic" },
    );

    Choice {
        backend: chosen_backend,
        precision: chosen_precision,
        parallelism: chosen_par,
    }
}

/// True when `spec`'s knobs need no resolution (fast path: concrete specs
/// pay one branch, no locks, no counters).
fn concrete(backend: Backend, precision: Precision) -> bool {
    backend != Backend::Auto && precision != Precision::Auto
}

/// Resolve a Gaussian spec's Auto knobs to the fastest legal concrete
/// configuration. A fully concrete spec is returned unchanged (and not
/// counted as a resolution).
pub fn resolve_gaussian(spec: &GaussianSpec) -> GaussianSpec {
    if concrete(spec.backend, spec.precision) {
        return *spec;
    }
    let workload = match spec.derivative {
        Derivative::Smooth => Workload::GaussianSmooth,
        Derivative::First => Workload::GaussianD1,
        Derivative::Second => Workload::GaussianD2,
    };
    let c = resolve_knobs(workload, spec.k, spec.backend, spec.precision, None, true);
    let mut out = *spec;
    out.backend = c.backend;
    out.precision = c.precision;
    out
}

/// Resolve a Morlet spec's Auto knobs. The f32 tier is only eligible under
/// the direct-SFT method (the spec layer's rule); other methods resolve
/// `Precision::Auto` to f64.
pub fn resolve_morlet(spec: &MorletSpec) -> MorletSpec {
    if concrete(spec.backend, spec.precision) {
        return *spec;
    }
    let f32_legal = matches!(spec.method, crate::morlet::Method::DirectSft { .. });
    let c = resolve_knobs(
        Workload::Morlet,
        spec.k,
        spec.backend,
        spec.precision,
        None,
        f32_legal,
    );
    let mut out = *spec;
    out.backend = c.backend;
    out.precision = c.precision;
    out
}

/// Resolve a scalogram spec's Auto knobs. The profile cell is looked up at
/// the grid's **largest** σ (the row that dominates cost); a profile row
/// may also pin the row fan-out that measured fastest, while the heuristic
/// keeps [`Parallelism::Auto`]'s adaptive meaning.
pub fn resolve_scalogram(spec: &ScalogramSpec) -> ScalogramSpec {
    if concrete(spec.backend, spec.precision) {
        return spec.clone();
    }
    let sigma_max = spec.sigmas.iter().cloned().fold(0.0f64, f64::max);
    let k = (3.0 * sigma_max).ceil() as usize;
    let c = resolve_knobs(
        Workload::Scalogram,
        k,
        spec.backend,
        spec.precision,
        Some(spec.parallelism),
        true,
    );
    let mut out = spec.clone();
    out.backend = c.backend;
    out.precision = c.precision;
    if let Some(par) = c.parallelism {
        out.parallelism = par;
    }
    out
}

/// Resolve a 2-D Gabor spec's Auto backend (the spec has no precision
/// knob). Falls back to the shape heuristic when the profile has no
/// [`Workload::Gabor2d`] rows — the default calibration grid does not
/// measure 2-D workloads.
pub fn resolve_gabor2d(spec: &Gabor2dSpec) -> Gabor2dSpec {
    if spec.backend != Backend::Auto {
        return *spec;
    }
    let k = (3.0 * spec.sigma).ceil() as usize;
    let c = resolve_knobs(
        Workload::Gabor2d,
        k,
        spec.backend,
        Precision::F64,
        Some(spec.parallelism),
        false,
    );
    let mut out = *spec;
    out.backend = c.backend;
    if let Some(par) = c.parallelism {
        out.parallelism = par;
    }
    out
}

/// Resolve any [`TransformSpec`]'s Auto knobs (variant-preserving).
pub fn resolve_spec(spec: &TransformSpec) -> TransformSpec {
    match spec {
        TransformSpec::Gaussian(s) => TransformSpec::Gaussian(resolve_gaussian(s)),
        TransformSpec::Morlet(s) => TransformSpec::Morlet(resolve_morlet(s)),
        TransformSpec::Scalogram(s) => TransformSpec::Scalogram(resolve_scalogram(s)),
        TransformSpec::Gabor2d(s) => TransformSpec::Gabor2d(resolve_gabor2d(s)),
    }
}

/// Resolve a bare backend knob for the legacy non-spec surfaces
/// ([`crate::gaussian::GaussianSmoother`], [`crate::image`]): profile row
/// first (under `workload`), shape heuristic otherwise. Concrete backends
/// pass through untouched.
pub fn resolve_backend(workload: Workload, k: usize, backend: Backend) -> Backend {
    if backend != Backend::Auto {
        return backend;
    }
    resolve_knobs(workload, k, backend, Precision::F64, None, false).backend
}

#[cfg(test)]
mod tests {
    use super::*;

    // The profile slot and counters are process-global; every test that
    // installs or clears a profile must hold this lock so parallel test
    // threads observe a consistent slot.
    static GLOBAL: Mutex<()> = Mutex::new(());

    fn gauss_auto(k: usize) -> GaussianSpec {
        GaussianSpec::builder(k as f64 / 3.0)
            .window(k)
            .backend(Backend::Auto)
            .precision(Precision::Auto)
            .build()
            .unwrap()
    }

    #[test]
    fn heuristic_resolution_is_simd_f64_for_wide_windows() {
        let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
        clear_profile();
        let r = resolve_gaussian(&gauss_auto(64));
        assert_eq!(r.backend, Backend::Simd);
        assert_eq!(r.precision, Precision::F64);
        let narrow = resolve_gaussian(&gauss_auto(4));
        assert_eq!(narrow.backend, Backend::PureRust);
    }

    #[test]
    fn concrete_specs_pass_through_uncounted() {
        let before = stats().resolutions;
        let spec = GaussianSpec::builder(8.0).build().unwrap();
        let r = resolve_gaussian(&spec);
        assert_eq!(r, spec);
        // other tests may resolve concurrently; this spec itself must not
        // have advanced the counter, which passing through proves only
        // when the count is stable — so just pin the pass-through value
        assert!(stats().resolutions >= before);
    }

    #[test]
    fn profile_row_decides_and_is_counted() {
        let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
        let mut p = Profile::new();
        p.insert(Decision {
            workload: Workload::GaussianSmooth,
            n: 65536,
            k: 64,
            backend: Backend::PureRust,
            precision: Precision::F32,
            parallelism: Parallelism::Auto,
            ns_per_elem: 0.5,
        });
        install_profile(p);
        let before = stats();
        let r = resolve_gaussian(&gauss_auto(64));
        clear_profile();
        assert_eq!(r.backend, Backend::PureRust);
        assert_eq!(r.precision, Precision::F32);
        let after = stats();
        assert!(after.profile_hits > before.profile_hits);
        assert!(after.last.contains("profile"));
    }

    #[test]
    fn illegal_f32_pick_is_demoted_to_f64() {
        let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
        let mut p = Profile::new();
        p.insert(Decision {
            workload: Workload::Morlet,
            n: 65536,
            k: 64,
            backend: Backend::Simd,
            precision: Precision::F32,
            parallelism: Parallelism::Auto,
            ns_per_elem: 0.5,
        });
        install_profile(p);
        let spec = MorletSpec::builder(64.0 / 3.0, 6.0)
            .window(64)
            .method(crate::morlet::Method::MultiplySft { p_m: 3 })
            .precision(Precision::Auto)
            .build()
            .unwrap();
        let r = resolve_morlet(&spec);
        clear_profile();
        // profile says f32, but the multiply method has no f32 tier
        assert_eq!(r.precision, Precision::F64);
        assert_eq!(r.backend, spec.backend);
    }

    #[test]
    fn runtime_backend_resolves_precision_to_f64() {
        let _g = GLOBAL.lock().unwrap_or_else(|e| e.into_inner());
        clear_profile();
        let spec = GaussianSpec::builder(8.0)
            .backend(Backend::Runtime)
            .precision(Precision::Auto)
            .build()
            .unwrap();
        let r = resolve_gaussian(&spec);
        assert_eq!(r.backend, Backend::Runtime);
        assert_eq!(r.precision, Precision::F64);
    }
}
