//! Table 1: relative RMSE of Gaussian smoothing and its differentials with
//! MMSE coefficients, SFT and ASFT, P = 2..6, K = 256, n₀ = 10, β tuned per
//! P to minimize e(G) (evaluation over [-3K, 3K], eq. 48).

use crate::coeffs::tuning::{gaussian_asft_table_rmse, gaussian_table_rmse, tune_beta_sigma};

/// One row of Table 1 (percentages, like the paper prints).
#[derive(Clone, Debug)]
pub struct Table1Row {
    /// "SFT" or "ASFT".
    pub transform: &'static str,
    /// Series order P.
    pub p: usize,
    /// e(G) in percent.
    pub e_g_pct: f64,
    /// e(G_D) in percent.
    pub e_gd_pct: f64,
    /// e(G_DD) in percent.
    pub e_gdd_pct: f64,
}

/// Regenerate Table 1 with the paper's parameters.
pub fn table1_rows() -> Vec<Table1Row> {
    table1_rows_with_k(256, 10)
}

/// Parameterized variant (tests use a smaller K for speed).
///
/// Per-P tuning covers both β *and* the effective K/σ ratio — see
/// [`tune_beta_sigma`] for why the paper's published column is only
/// reachable as the lower envelope over the ratio.
pub fn table1_rows_with_k(k: usize, n0: i64) -> Vec<Table1Row> {
    let mut rows = Vec::new();
    for p in 2..=6usize {
        let (sigma, beta, _) = tune_beta_sigma(k, p);
        let (g, gd, gdd) = gaussian_table_rmse(sigma, k, p, beta);
        rows.push(Table1Row {
            transform: "SFT",
            p,
            e_g_pct: 100.0 * g,
            e_gd_pct: 100.0 * gd,
            e_gdd_pct: 100.0 * gdd,
        });
    }
    for p in 2..=6usize {
        let (sigma, beta, _) = tune_beta_sigma(k, p);
        let (g, gd, gdd) = gaussian_asft_table_rmse(sigma, k, p, beta, n0);
        rows.push(Table1Row {
            transform: "ASFT",
            p,
            e_g_pct: 100.0 * g,
            e_gd_pct: 100.0 * gd,
            e_gdd_pct: 100.0 * gdd,
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_matches_paper_magnitudes() {
        // Paper Table 1 (K=256): SFT e(G): P=2→1.0%, P=3→0.15%, P=4→0.038%,
        // P=5→0.0059%, P=6→0.0015%. Check each of our tuned values lands
        // within a small factor (same decade, same monotone decay).
        let rows = table1_rows_with_k(256, 10);
        let paper_g = [1.0, 0.15, 0.038, 0.0059, 0.0015];
        for (i, want) in paper_g.iter().enumerate() {
            let got = rows[i].e_g_pct;
            assert!(
                got < want * 4.0 && got > want * 0.1,
                "SFT P={} e(G): got {got}% vs paper {want}%",
                rows[i].p
            );
        }
        // differentials are worse than the plain fit at every P (paper shape)
        for r in &rows {
            assert!(r.e_gd_pct > r.e_g_pct, "P={} {:?}", r.p, r.transform);
            assert!(r.e_gdd_pct > r.e_gd_pct, "P={} {:?}", r.p, r.transform);
        }
    }

    #[test]
    fn asft_rows_close_to_sft_rows() {
        // Paper: ASFT only slightly worse (e.g. P=4: 0.038 → 0.046).
        let rows = table1_rows_with_k(128, 5);
        for p_idx in 0..5 {
            let sft = &rows[p_idx];
            let asft = &rows[p_idx + 5];
            assert_eq!(sft.p, asft.p);
            assert!(
                asft.e_g_pct < sft.e_g_pct * 5.0 + 0.01,
                "P={}: ASFT {} vs SFT {}",
                sft.p,
                asft.e_g_pct,
                sft.e_g_pct
            );
        }
    }
}
