//! Fig. 5: relative RMSE of the approximated Morlet wavelet vs ξ ∈ [1, 20]
//! for the direct (P_D ∈ {5,7,9,11}) and multiplication (P_M ∈ {2..5})
//! methods, SFT and ASFT (σ = 60, K tuned per point, eq. 66).
//!
//! Fig. 6: the P_D = 6 direct method vs the `[-3σ, 3σ]`-truncated wavelet.

use crate::coeffs::tuning::morlet_kernel_rmse;
use crate::coeffs::{morlet_point, morlet_taps};
use crate::dsp::Complex;
use crate::morlet::{Method, MorletTransform};

/// One (ξ, variant) point.
#[derive(Clone, Debug)]
pub struct Fig5Row {
    /// Paper Table 2 abbreviation, e.g. "MDP7", "MMS5P3".
    pub variant: String,
    /// Shape factor ξ of this point.
    pub xi: f64,
    /// Effective-kernel relative RMSE (eq. 66).
    pub rmse: f64,
    /// the tuned window half-width
    pub k: usize,
}

const SIGMA: f64 = 60.0;

/// RMSE of a method at (σ=60, ξ), with K searched over a grid around 3σ
/// ("K is chosen such that the relative RMSE becomes the smallest").
fn best_over_k(xi: f64, method: Method, eval_r_mult: usize) -> (f64, usize) {
    let mut best = (f64::INFINITY, 0usize);
    for mult in [2.4f64, 2.7, 3.0, 3.3, 3.6] {
        let k = (mult * SIGMA).round() as usize;
        let Ok(mt) = MorletTransform::with_k(SIGMA, xi, k, method) else {
            continue;
        };
        let kern = mt.effective_kernel(eval_r_mult * k);
        let e = morlet_kernel_rmse(&kern, SIGMA, xi);
        if e < best.0 {
            best = (e, k);
        }
    }
    best
}

/// The paper's Fig. 5 variant grid.
pub fn fig5_variants() -> Vec<(String, Method)> {
    let mut v: Vec<(String, Method)> = Vec::new();
    for p_d in [5usize, 7, 9, 11] {
        v.push((format!("MDP{p_d}"), Method::DirectSft { p_d }));
    }
    for p_d in [5usize, 7, 9, 11] {
        v.push((format!("MDS5P{p_d}"), Method::DirectAsft { p_d, n0: 5 }));
    }
    for p_m in [2usize, 3, 4, 5] {
        v.push((format!("MMP{p_m}"), Method::MultiplySft { p_m }));
    }
    for p_m in [2usize, 3, 4, 5] {
        v.push((format!("MMS5P{p_m}"), Method::MultiplyAsft { p_m, n0: 5 }));
    }
    v
}

/// Regenerate Fig. 5. `xis` defaults to 1..=20 in the CLI; tests use fewer.
pub fn fig5_rows(xis: &[f64]) -> Vec<Fig5Row> {
    let mut rows = Vec::new();
    for &xi in xis {
        for (name, method) in fig5_variants() {
            let (rmse, k) = best_over_k(xi, method, 5);
            rows.push(Fig5Row {
                variant: name,
                xi,
                rmse,
                k,
            });
        }
    }
    rows
}

/// Fig. 6: MDP6 (SFT, ASFT) versus the truncated wavelet baseline.
pub fn fig6_rows(xis: &[f64]) -> Vec<Fig5Row> {
    let mut rows = Vec::new();
    for &xi in xis {
        let (e_sft, k1) = best_over_k(xi, Method::DirectSft { p_d: 6 }, 5);
        let (e_asft, k2) = best_over_k(xi, Method::DirectAsft { p_d: 6, n0: 5 }, 5);
        rows.push(Fig5Row {
            variant: "MDP6".into(),
            xi,
            rmse: e_sft,
            k: k1,
        });
        rows.push(Fig5Row {
            variant: "MDS5P6".into(),
            xi,
            rmse: e_asft,
            k: k2,
        });
        rows.push(Fig5Row {
            variant: "MCT3".into(),
            xi,
            rmse: truncated_rmse(xi),
            k: (3.0 * SIGMA) as usize,
        });
    }
    rows
}

/// RMSE of ψ truncated to [-3σ, 3σ] against ψ on [-5K, 5K] (the Fig. 6
/// reference curve: pure truncation error, no fit involved).
fn truncated_rmse(xi: f64) -> f64 {
    let k = (3.0 * SIGMA) as usize;
    let r = 5 * k;
    let taps = morlet_taps(SIGMA, xi, k);
    let mut kern = vec![Complex::zero(); 2 * r + 1];
    for (i, t) in taps.into_iter().enumerate() {
        kern[r - k + i] = t;
    }
    // reuse the generic kernel RMSE (it re-evaluates ψ exactly)
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, n) in (-(r as isize)..=r as isize).enumerate() {
        let exact = morlet_point(SIGMA, xi, n as f64);
        num += (kern[i] - exact).norm_sq();
        den += exact.norm_sq();
    }
    (num / den).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn direct_improves_with_pd() {
        // At ξ=6 the paper's curves order MDP5 > MDP7 > MDP9 in RMSE.
        let e5 = best_over_k(6.0, Method::DirectSft { p_d: 5 }, 5).0;
        let e7 = best_over_k(6.0, Method::DirectSft { p_d: 7 }, 5).0;
        let e9 = best_over_k(6.0, Method::DirectSft { p_d: 9 }, 5).0;
        assert!(e5 > e7, "{e5} vs {e7}");
        assert!(e7 > e9, "{e7} vs {e9}");
    }

    #[test]
    fn matched_cost_parity_at_moderate_xi() {
        // Paper: P_D = 2·P_M + 1 gives comparable RMSE for ξ >= 6.
        let ed = best_over_k(8.0, Method::DirectSft { p_d: 7 }, 5).0;
        let em = best_over_k(8.0, Method::MultiplySft { p_m: 3 }, 5).0;
        assert!(
            ed / em < 10.0 && em / ed < 10.0,
            "direct {ed} vs multiply {em}"
        );
    }

    #[test]
    fn multiply_worse_at_small_xi() {
        // Paper: for small ξ the multiply method is clearly worse.
        let ed = best_over_k(1.5, Method::DirectSft { p_d: 7 }, 5).0;
        let em = best_over_k(1.5, Method::MultiplySft { p_m: 3 }, 5).0;
        assert!(em > ed, "multiply {em} should exceed direct {ed} at xi=1.5");
    }

    #[test]
    fn fig6_sft_comparable_to_truncation() {
        // Paper Fig. 6: MDP6 RMSE ≈ the [-3σ,3σ] truncation RMSE.
        let rows = fig6_rows(&[6.0]);
        let sft = rows.iter().find(|r| r.variant == "MDP6").unwrap();
        let trunc = rows.iter().find(|r| r.variant == "MCT3").unwrap();
        assert!(
            sft.rmse < trunc.rmse * 20.0,
            "MDP6 {} vs MCT3 {}",
            sft.rmse,
            trunc.rmse
        );
    }

    #[test]
    fn asft_close_to_sft_at_moderate_xi() {
        let rows = fig6_rows(&[8.0]);
        let sft = rows.iter().find(|r| r.variant == "MDP6").unwrap();
        let asft = rows.iter().find(|r| r.variant == "MDS5P6").unwrap();
        assert!(
            asft.rmse < sft.rmse * 10.0 + 1e-4,
            "ASFT {} vs SFT {}",
            asft.rmse,
            sft.rmse
        );
    }
}
