//! Figs. 8-9: calculation time of Gaussian smoothing (Fig. 8) and the Morlet
//! wavelet transform (Fig. 9), proposed method vs truncated convolution.
//!
//! Two data sources (the [DESIGN.md §2](crate::design) substitution):
//!
//! * `*_model_rows` — the calibrated GPU step-count model (`gpu_model`),
//!   which reproduces the paper's reported series (who wins, crossover,
//!   the 0.545 ms / 413.6× headline);
//! * `*_cpu_rows` — real single-thread wall-clock of this crate's own
//!   implementations, which runs the *same asymptotic race* (O(PN) vs
//!   O(σN)) on the machine at hand.

use crate::dsp::gaussian_noise;
use crate::gaussian::GaussianSmoother;
use crate::gpu_model::GpuModel;
use crate::morlet::{Method, MorletTransform};
use crate::util::bench::Bench;

/// One sweep point: `x` is N (sweep in N) or σ (sweep in σ).
#[derive(Clone, Debug)]
pub struct TimingRow {
    /// Sweep coordinate (N or σ).
    pub x: f64,
    /// Truncated-convolution time (ms).
    pub conv_ms: f64,
    /// Proposed-method time (ms).
    pub proposed_ms: f64,
}

impl TimingRow {
    /// Ratio conv/proposed (the paper's reported speedup).
    pub fn speedup(&self) -> f64 {
        self.conv_ms / self.proposed_ms
    }
}

/// Paper Fig. 8(a,b): N from 100 to 102400 at σ = 16; Fig. 8(c,d): σ from 16
/// to 8192 at N = 102400. `sweep_n = true` selects the N sweep.
pub fn fig8_model_rows(sweep_n: bool) -> Vec<TimingRow> {
    let m = GpuModel::rtx3090();
    sweep_points(sweep_n)
        .into_iter()
        .map(|(n, sigma)| TimingRow {
            x: if sweep_n { n as f64 } else { sigma },
            conv_ms: m.conv_gaussian_ns(n, sigma) / 1e6,
            proposed_ms: m.proposed_gaussian_ns(n, sigma) / 1e6,
        })
        .collect()
}

/// Fig. 9 equivalents for the Morlet transform.
pub fn fig9_model_rows(sweep_n: bool) -> Vec<TimingRow> {
    let m = GpuModel::rtx3090();
    sweep_points(sweep_n)
        .into_iter()
        .map(|(n, sigma)| TimingRow {
            x: if sweep_n { n as f64 } else { sigma },
            conv_ms: m.conv_morlet_ns(n, sigma) / 1e6,
            proposed_ms: m.proposed_morlet_ns(n, sigma) / 1e6,
        })
        .collect()
}

fn sweep_points(sweep_n: bool) -> Vec<(usize, f64)> {
    if sweep_n {
        [100usize, 200, 400, 800, 1600, 3200, 6400, 12800, 25600, 51200, 102400]
            .iter()
            .map(|&n| (n, 16.0))
            .collect()
    } else {
        [16.0f64, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0]
            .iter()
            .map(|&s| (102400usize, s))
            .collect()
    }
}

/// Smaller sweep grids for the real-CPU measurements (the conv baseline is
/// O(Nσ); full paper grids would take minutes per point).
fn cpu_sweep_points(sweep_n: bool, quick: bool) -> Vec<(usize, f64)> {
    if sweep_n {
        let ns: &[usize] = if quick {
            &[100, 1600, 12800]
        } else {
            &[100, 400, 1600, 6400, 25600, 102400]
        };
        ns.iter().map(|&n| (n, 16.0)).collect()
    } else {
        let sigmas: &[f64] = if quick {
            &[16.0, 128.0, 512.0]
        } else {
            &[16.0, 64.0, 256.0, 1024.0, 4096.0, 8192.0]
        };
        let n = if quick { 12800 } else { 102400 };
        sigmas.iter().map(|&s| (n, s)).collect()
    }
}

/// Real CPU wall-clock, Gaussian smoothing: GCT3 vs GDP6 (kernel integral).
pub fn fig8_cpu_rows(sweep_n: bool, quick: bool) -> Vec<TimingRow> {
    let bench = if quick { Bench::quick() } else { Bench::default() };
    cpu_sweep_points(sweep_n, quick)
        .into_iter()
        .map(|(n, sigma)| {
            let x = gaussian_noise(n, 1.0, 42);
            let sm = GaussianSmoother::new(sigma, 6).unwrap();
            let conv = bench.run("gct3", || sm.smooth_direct(&x));
            let prop = bench.run("gdp6", || sm.smooth_sft(&x));
            TimingRow {
                x: if sweep_n { n as f64 } else { sigma },
                conv_ms: conv.median_ns / 1e6,
                proposed_ms: prop.median_ns / 1e6,
            }
        })
        .collect()
}

/// Real CPU wall-clock, Morlet transform: MCT3 vs MDP6.
pub fn fig9_cpu_rows(sweep_n: bool, quick: bool) -> Vec<TimingRow> {
    let bench = if quick { Bench::quick() } else { Bench::default() };
    cpu_sweep_points(sweep_n, quick)
        .into_iter()
        .map(|(n, sigma)| {
            let x = gaussian_noise(n, 1.0, 43);
            let conv_t = MorletTransform::new(sigma, 6.0, Method::TruncatedConv).unwrap();
            let prop_t = MorletTransform::new(sigma, 6.0, Method::DirectSft { p_d: 6 }).unwrap();
            let conv = bench.run("mct3", || conv_t.transform(&x));
            let prop = bench.run("mdp6", || prop_t.transform(&x));
            TimingRow {
                x: if sweep_n { n as f64 } else { sigma },
                conv_ms: conv.median_ns / 1e6,
                proposed_ms: prop.median_ns / 1e6,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_sigma_sweep_shapes() {
        let rows = fig9_model_rows(false);
        // conv grows ~linearly with σ; proposed grows ~logarithmically
        let first = &rows[0];
        let last = rows.last().unwrap();
        assert!(last.conv_ms / first.conv_ms > 100.0);
        assert!(last.proposed_ms / first.proposed_ms < 5.0);
        // headline point: ~0.545 ms and ~413× at σ=8192
        assert!((last.proposed_ms - 0.545).abs() / 0.545 < 0.2, "{}", last.proposed_ms);
        assert!(last.speedup() > 300.0, "{}", last.speedup());
    }

    #[test]
    fn model_n_sweep_shapes() {
        let rows = fig8_model_rows(true);
        // at σ=16, conv is a little faster for small N (paper Fig. 8 b)
        assert!(rows[0].conv_ms <= rows[0].proposed_ms);
        // and the proposed time is flat while N <= cores
        let flat = rows.iter().filter(|r| r.x <= 10496.0).collect::<Vec<_>>();
        let tmin = flat.iter().map(|r| r.proposed_ms).fold(f64::MAX, f64::min);
        let tmax = flat.iter().map(|r| r.proposed_ms).fold(0.0f64, f64::max);
        assert!(tmax / tmin < 2.0, "proposed should be ~flat below M cores");
    }

    #[test]
    fn cpu_rows_reproduce_the_asymptotic_race() {
        // quick grid: conv time grows with σ, proposed stays ~flat
        let rows = fig9_cpu_rows(false, true);
        let first = &rows[0];
        let last = rows.last().unwrap();
        assert!(
            last.conv_ms > 3.0 * first.conv_ms,
            "conv: {} -> {}",
            first.conv_ms,
            last.conv_ms
        );
        assert!(
            last.proposed_ms < 4.0 * first.proposed_ms,
            "proposed: {} -> {}",
            first.proposed_ms,
            last.proposed_ms
        );
        // by σ=512 the proposed method must win on CPU too
        assert!(last.speedup() > 2.0, "{}", last.speedup());
    }
}
