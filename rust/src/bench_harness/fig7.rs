//! Fig. 7: the optimal first order P_S of the direct method versus ξ
//! (σ = 60, P_D = 6). The paper observes P_S increases with ξ.

use crate::coeffs::optimal_ps;

/// One (ξ, optimal P_S) point.
#[derive(Clone, Debug)]
pub struct Fig7Row {
    /// Shape factor ξ.
    pub xi: f64,
    /// Optimal first order P_S found by the search.
    pub p_s: usize,
    /// Fit RMSE at that P_S.
    pub rmse: f64,
}

/// Run the optimal-P_S search at σ = 60, P_D = 6 for each ξ.
pub fn fig7_rows(xis: &[f64]) -> Vec<Fig7Row> {
    let sigma = 60.0;
    let k = 180; // 3σ
    let beta = std::f64::consts::PI / k as f64;
    xis.iter()
        .map(|&xi| {
            let (p_s, rmse) = optimal_ps(sigma, xi, k, 6, beta);
            Fig7Row { xi, p_s, rmse }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ps_monotone_trend_with_xi() {
        let rows = fig7_rows(&[2.0, 6.0, 10.0, 14.0, 18.0]);
        // overall increasing trend (paper Fig. 7); allow local ties
        assert!(rows.windows(2).all(|w| w[1].p_s >= w[0].p_s));
        assert!(rows.last().unwrap().p_s > rows[0].p_s + 3);
    }

    #[test]
    fn ps_tracks_carrier_band() {
        // P_S + (P_D-1)/2 should sit near the carrier order ξK/(σπ)
        let rows = fig7_rows(&[6.0, 12.0]);
        for r in rows {
            let carrier = r.xi * 180.0 / (60.0 * std::f64::consts::PI);
            let centre = r.p_s as f64 + 2.5;
            assert!(
                (centre - carrier).abs() <= 3.0,
                "xi={}: centre {centre} vs carrier {carrier}",
                r.xi
            );
        }
    }
}
