//! Regeneration harness for every table and figure in the paper's evaluation
//! (§5): Table 1 and Figs. 5-9, plus the f32-drift ablation. Each generator
//! returns structured rows and renders both an aligned text table (what the
//! CLI prints) and CSV (for plotting).
//!
//! See [DESIGN.md §4](crate::design) for the experiment index and
//! acceptance criteria.

mod fig5;
mod fig7;
mod fig89;
mod table1;

pub use fig5::{fig5_rows, fig6_rows, Fig5Row};
pub use fig7::{fig7_rows, Fig7Row};
pub use fig89::{fig8_cpu_rows, fig8_model_rows, fig9_cpu_rows, fig9_model_rows, TimingRow};
pub use table1::{table1_rows, table1_rows_with_k, Table1Row};

/// Render rows as an aligned text table.
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        cells
            .iter()
            .zip(widths)
            .map(|(c, w)| format!("{c:>w$}"))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let mut out = String::new();
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&head, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Render rows as CSV with the given header.
pub fn render_csv(headers: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = headers.join(",");
    out.push('\n');
    for row in rows {
        out.push_str(&row.join(","));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renderer_aligns() {
        let t = render_table(
            &["a", "bbbb"],
            &[
                vec!["1".into(), "2".into()],
                vec!["100".into(), "2000000".into()],
            ],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[3].contains("100"));
        assert_eq!(lines[0].len(), lines[2].len());
    }

    #[test]
    fn csv_renderer() {
        let c = render_csv(&["x", "y"], &[vec!["1".into(), "2".into()]]);
        assert_eq!(c, "x,y\n1,2\n");
    }
}
