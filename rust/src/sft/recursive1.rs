//! First-order recursive-filter SFT (paper §2.3, eqs. 22-28).
//!
//! `v[n] = e^{-iβp} v[n-1] + x[n]` accumulates `Σ_k e^{-iβpk} x[n-k]`;
//! truncating the window by delayed subtraction at lag 2K (eq. 25 — cheaper
//! than 2K+1 because `e^{-iβp·2K} = 1` for the harmonic SFT) and reading at
//! delay K gives (eq. 27):
//!
//! ```text
//! c_p[n] − i s_p[n] = (−1)^p ( v_(2K)[n+K] + x[n−K] )
//! ```
//!
//! Integer orders and β = π/K only.  The filter state `v[n]` is a running sum
//! over the whole history — in f32 its rounding error grows with N, which is
//! the instability ASFT fixes (§2.4; measured in [`crate::precision`]).

use super::Components;
use crate::dsp::{Complex, Float};

/// `(c_p, s_p)` via the first-order recursive filter (direct form, eq. 28).
pub fn components<T: Float>(x: &[T], k: usize, p: usize) -> Components<T> {
    let n = x.len();
    let beta = std::f64::consts::PI / k as f64;
    let pole = Complex::<T>::cis(T::from_f64(-beta * p as f64));
    let sign = if p % 2 == 0 { T::ONE } else { -T::ONE };
    let get = |j: isize| -> T {
        if j >= 0 && (j as usize) < n {
            x[j as usize]
        } else {
            T::ZERO
        }
    };

    // Direct recurrence for the truncated filter (eq. 28):
    //   v2k[m] = e^{-iβp} v2k[m-1] + x[m] - x[m-2K]
    // We read v2k at m = n + K for n in [0, N): run m from 0 .. N+K.
    let ki = k as isize;
    let l2 = 2 * k as isize;
    let mut v = Complex::<T>::zero();
    let mut c = Vec::with_capacity(n);
    let mut s = Vec::with_capacity(n);
    for m in 0..(n as isize + ki) {
        v = pole * v + Complex::from_re(get(m) - get(m - l2));
        if m >= ki {
            let i = m - ki; // output index n = m - K
            let out = (v + Complex::from_re(get(i - ki))).scale(sign);
            c.push(out.re);
            s.push(-out.im);
        }
    }
    debug_assert_eq!(c.len(), n);
    Components { c, s }
}

/// Untruncated filter state `v[n]` (eq. 22) over the signal — exposed for the
/// precision study: its magnitude grows with N, ASFT's does not.
pub fn filter_state<T: Float>(x: &[T], k: usize, p: usize) -> Vec<Complex<T>> {
    let beta = std::f64::consts::PI / k as f64;
    let pole = Complex::<T>::cis(T::from_f64(-beta * p as f64));
    let mut v = Complex::<T>::zero();
    x.iter()
        .map(|&xv| {
            v = pole * v + Complex::from_re(xv);
            v
        })
        .collect()
}

/// 2K+1-truncation variant (eqs. 24, 26), kept for completeness/ablation:
/// one extra complex multiply per output versus [`components`].
pub fn components_2k1<T: Float>(x: &[T], k: usize, p: usize) -> Components<T> {
    let n = x.len();
    let beta = std::f64::consts::PI / k as f64;
    let pole = Complex::<T>::cis(T::from_f64(-beta * p as f64));
    let sign = if p % 2 == 0 { T::ONE } else { -T::ONE };
    let get = |j: isize| -> T {
        if j >= 0 && (j as usize) < n {
            x[j as usize]
        } else {
            T::ZERO
        }
    };
    let ki = k as isize;
    let l = 2 * k as isize + 1;
    // v_(2K+1)[m] = e^{-iβp} v_(2K+1)[m-1] + x[m] - e^{-iβp(2K+1)} x[m-2K-1]
    // and e^{-iβp(2K+1)} = e^{-iβp} for harmonic β (paper's eq. 24 remark).
    let mut v = Complex::<T>::zero();
    let mut c = Vec::with_capacity(n);
    let mut s = Vec::with_capacity(n);
    for m in 0..(n as isize + ki) {
        v = pole * v + Complex::from_re(get(m)) - pole.scale(get(m - l));
        if m >= ki {
            let out = v.scale(sign); // eq. 26
            c.push(out.re);
            s.push(-out.im);
        }
    }
    Components { c, s }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::{gaussian_noise, rel_rmse};
    use crate::sft::direct;

    #[test]
    fn truncation_2k_matches_direct() {
        let x: Vec<f64> = gaussian_noise(220, 1.0, 4);
        let k = 16;
        let beta = std::f64::consts::PI / 16.0;
        for p in [0, 1, 2, 9] {
            let got = components(&x, k, p);
            let want = direct::components(&x, k, beta, p as f64);
            assert!(rel_rmse(&got.c, &want.c) < 1e-10, "p={p}");
            assert!(rel_rmse(&got.s, &want.s) < 1e-10, "p={p}");
        }
    }

    #[test]
    fn truncation_2k1_matches_direct() {
        let x: Vec<f64> = gaussian_noise(180, 1.0, 6);
        let k = 12;
        let beta = std::f64::consts::PI / 12.0;
        for p in [0, 3, 5] {
            let got = components_2k1(&x, k, p);
            let want = direct::components(&x, k, beta, p as f64);
            assert!(rel_rmse(&got.c, &want.c) < 1e-10, "p={p}");
            assert!(rel_rmse(&got.s, &want.s) < 1e-10, "p={p}");
        }
    }

    #[test]
    fn both_truncations_agree() {
        let x: Vec<f64> = gaussian_noise(100, 2.0, 9);
        let a = components(&x, 8, 3);
        let b = components_2k1(&x, 8, 3);
        assert!(rel_rmse(&a.c, &b.c) < 1e-10);
        assert!(rel_rmse(&a.s, &b.s) < 1e-10);
    }

    #[test]
    fn filter_state_is_running_modulated_sum() {
        let x = vec![1.0f64; 10];
        let v = filter_state(&x, 4, 0); // p=0: pole=1, pure running sum
        for (i, vi) in v.iter().enumerate() {
            assert!((vi.re - (i + 1) as f64).abs() < 1e-12);
            assert!(vi.im.abs() < 1e-12);
        }
    }

    #[test]
    fn f32_instantiation_small_signal() {
        let x: Vec<f32> = gaussian_noise(64, 1.0, 1)
            .into_iter()
            .map(|v| v as f32)
            .collect();
        let got = components(&x, 6, 2);
        let x64: Vec<f64> = x.iter().map(|&v| v as f64).collect();
        let want = direct::components(&x64, 6, std::f64::consts::PI / 6.0, 2.0);
        let got_c: Vec<f64> = got.c.iter().map(|&v| v as f64).collect();
        assert!(rel_rmse(&got_c, &want.c) < 1e-4);
    }
}
