//! Second-order recursive-filter SFT (paper §2.3, eqs. 30-31; Sugimoto-style).
//!
//! Eliminating the complex pole between consecutive steps of the first-order
//! filter yields a recurrence whose *state* multipliers are real:
//!
//! ```text
//! v[n] = 2cos(βp)·v[n-1] − v[n-2] + x[n] − e^{iβp}·x[n-1]
//! ```
//!
//! so real and imaginary parts propagate independently (two real biquads).
//! The paper notes this resembles a second-order difference equation and "might
//! result in a large calculation error by floating-point operations" — we keep
//! it faithful and measure exactly that in [`crate::precision`].

use super::Components;
use crate::dsp::Float;

/// `(c_p, s_p)` via the truncated second-order recurrence (eq. 31).
pub fn components<T: Float>(x: &[T], k: usize, p: usize) -> Components<T> {
    let n = x.len();
    let beta = std::f64::consts::PI / k as f64;
    let two_cos = T::from_f64(2.0 * (beta * p as f64).cos());
    let cos_bp = T::from_f64((beta * p as f64).cos());
    let sin_bp = T::from_f64((beta * p as f64).sin());
    let sign = if p % 2 == 0 { T::ONE } else { -T::ONE };
    let get = |j: isize| -> T {
        if j >= 0 && (j as usize) < n {
            x[j as usize]
        } else {
            T::ZERO
        }
    };

    let ki = k as isize;
    let l2 = 2 * k as isize;
    // v2k[m] = 2cos(βp) v2k[m-1] − v2k[m-2] + d[m] − e^{iβp} d[m-1]
    //   where d[m] = x[m] − x[m−2K]      (eq. 31)
    let (mut vre1, mut vre2, mut vim1, mut vim2) = (T::ZERO, T::ZERO, T::ZERO, T::ZERO);
    let mut c = Vec::with_capacity(n);
    let mut s = Vec::with_capacity(n);
    for m in 0..(n as isize + ki) {
        let d = get(m) - get(m - l2);
        let d1 = get(m - 1) - get(m - 1 - l2);
        let vre = two_cos * vre1 - vre2 + d - cos_bp * d1;
        let vim = two_cos * vim1 - vim2 - sin_bp * d1;
        vre2 = vre1;
        vre1 = vre;
        vim2 = vim1;
        vim1 = vim;
        if m >= ki {
            let i = m - ki;
            // eq. 27 mapping shared with the first-order filter
            let out_re = sign * (vre + get(i - ki));
            let out_im = sign * vim;
            c.push(out_re);
            s.push(-out_im);
        }
    }
    debug_assert_eq!(c.len(), n);
    Components { c, s }
}

/// Untruncated second-order filter state (eq. 30) — for the precision study.
pub fn filter_state<T: Float>(x: &[T], k: usize, p: usize) -> Vec<(T, T)> {
    let n = x.len();
    let beta = std::f64::consts::PI / k as f64;
    let two_cos = T::from_f64(2.0 * (beta * p as f64).cos());
    let cos_bp = T::from_f64((beta * p as f64).cos());
    let sin_bp = T::from_f64((beta * p as f64).sin());
    let (mut vre1, mut vre2, mut vim1, mut vim2) = (T::ZERO, T::ZERO, T::ZERO, T::ZERO);
    let mut out = Vec::with_capacity(n);
    for m in 0..n {
        let d = x[m];
        let d1 = if m >= 1 { x[m - 1] } else { T::ZERO };
        let vre = two_cos * vre1 - vre2 + d - cos_bp * d1;
        let vim = two_cos * vim1 - vim2 - sin_bp * d1;
        vre2 = vre1;
        vre1 = vre;
        vim2 = vim1;
        vim1 = vim;
        out.push((vre, vim));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::{gaussian_noise, rel_rmse};
    use crate::sft::{direct, recursive1};

    #[test]
    fn matches_direct() {
        let x: Vec<f64> = gaussian_noise(240, 1.0, 21);
        let k = 20;
        let beta = std::f64::consts::PI / 20.0;
        for p in [0, 1, 4, 10] {
            let got = components(&x, k, p);
            let want = direct::components(&x, k, beta, p as f64);
            assert!(rel_rmse(&got.c, &want.c) < 1e-8, "p={p}");
            assert!(rel_rmse(&got.s, &want.s) < 1e-8, "p={p}");
        }
    }

    #[test]
    fn state_matches_first_order_state() {
        // Same v[n] by construction (paper §2.3), different rounding.
        let x: Vec<f64> = gaussian_noise(96, 1.0, 2);
        let k = 8;
        let p = 3;
        let s1 = recursive1::filter_state(&x, k, p);
        let s2 = filter_state(&x, k, p);
        for i in 0..x.len() {
            assert!((s1[i].re - s2[i].0).abs() < 1e-9, "re i={i}");
            assert!((s1[i].im - s2[i].1).abs() < 1e-9, "im i={i}");
        }
    }

    #[test]
    fn nyquist_order_alternates_sign() {
        // p = K: cos(βpk) = cos(πk) = (−1)^k
        let x: Vec<f64> = gaussian_noise(60, 1.0, 3);
        let k = 6;
        let got = components(&x, k, k);
        let want = direct::components(&x, k, std::f64::consts::PI / 6.0, k as f64);
        assert!(rel_rmse(&got.c, &want.c) < 1e-8);
    }
}
