//! Attenuated SFT (paper §2.4, eqs. 32-39).
//!
//! Components with exponentially attenuated window weights:
//!
//! ```text
//! c̃_p[n] = Σ_{k=-K}^{K} x[n-k] e^{-αk} cos(βpk)     (and s̃_p with sin)
//! ```
//!
//! **Convention** ([DESIGN.md §1.1](crate::design) errata): the weight is `e^{-αk}` — the sign under
//! which the paper's *stable* filter (34), with pole `e^{-α-iβp}`, computes
//! these components, and under which the Gaussian shift identity (eq. 40)
//! recovers exact smoothing via `n₀ = α/(2γ)`:
//! `x_G[n] ≈ e^{-α²/4γ} Σ_p a_p c̃_p[n-n₀]` (see [`crate::gaussian`]).
//!
//! The point of the attenuation: the filter state `ṽ[n]` is a *geometrically
//! weighted* history sum, hence bounded for bounded input, so single-precision
//! rounding error stops accumulating (measured in [`crate::precision`]).

use super::Components;
use crate::dsp::{Complex, Float};

/// `(c̃_p, s̃_p)` via the attenuated first-order filter (eqs. 34-37).
///
/// Reading the truncated filter at delay K and rescaling:
/// `c̃ − i·s̃ = (−1)^p e^{+αK} ( ṽ_(2K)[n+K] + e^{-2αK} x[n−K] )`.
pub fn components_r1<T: Float>(x: &[T], k: usize, p: usize, alpha: f64) -> Components<T> {
    let n = x.len();
    let beta = std::f64::consts::PI / k as f64;
    // pole q = e^{-α-iβp}  (eq. 34)
    let decay = T::from_f64((-alpha).exp());
    let pole = Complex::<T>::cis(T::from_f64(-beta * p as f64)).scale(decay);
    let q2k = T::from_f64((-alpha * 2.0 * k as f64).exp()); // e^{-2αK} (real: βp·2K ≡ 0 mod 2π)
    let scale = T::from_f64((alpha * k as f64).exp());
    let sign = if p % 2 == 0 { T::ONE } else { -T::ONE };
    let get = |j: isize| -> T {
        if j >= 0 && (j as usize) < n {
            x[j as usize]
        } else {
            T::ZERO
        }
    };

    // Truncated recurrence (eq. 37):
    //   ṽ2k[m] = q ṽ2k[m-1] + x[m] − e^{-2αK} x[m−2K]
    let ki = k as isize;
    let l2 = 2 * k as isize;
    let mut v = Complex::<T>::zero();
    let mut c = Vec::with_capacity(n);
    let mut s = Vec::with_capacity(n);
    for m in 0..(n as isize + ki) {
        v = pole * v + Complex::from_re(get(m) - q2k * get(m - l2));
        if m >= ki {
            let i = m - ki;
            let out = (v + Complex::from_re(q2k * get(i - ki))).scale(sign * scale);
            c.push(out.re);
            s.push(-out.im);
        }
    }
    Components { c, s }
}

/// `(c̃_p, s̃_p)` via the attenuated second-order filter (eqs. 38-39).
pub fn components_r2<T: Float>(x: &[T], k: usize, p: usize, alpha: f64) -> Components<T> {
    let n = x.len();
    let beta = std::f64::consts::PI / k as f64;
    let ea = (-alpha).exp();
    let two_ea_cos = T::from_f64(2.0 * ea * (beta * p as f64).cos());
    let e2a = T::from_f64(ea * ea);
    let ea_cos = T::from_f64(ea * (beta * p as f64).cos());
    let ea_sin = T::from_f64(ea * (beta * p as f64).sin());
    let q2k = T::from_f64((-alpha * 2.0 * k as f64).exp());
    let scale = T::from_f64((alpha * k as f64).exp());
    let sign = if p % 2 == 0 { T::ONE } else { -T::ONE };
    let get = |j: isize| -> T {
        if j >= 0 && (j as usize) < n {
            x[j as usize]
        } else {
            T::ZERO
        }
    };

    // eq. 39:  ṽ2k[m] = 2e^{-α}cos(βp) ṽ2k[m-1] − e^{-2α} ṽ2k[m-2]
    //                   + d[m] − e^{-α}e^{iβp} d[m-1]
    //          with d[m] = x[m] − e^{-2αK} x[m−2K]
    let ki = k as isize;
    let l2 = 2 * k as isize;
    let (mut vre1, mut vre2, mut vim1, mut vim2) = (T::ZERO, T::ZERO, T::ZERO, T::ZERO);
    let mut c = Vec::with_capacity(n);
    let mut s = Vec::with_capacity(n);
    for m in 0..(n as isize + ki) {
        let d = get(m) - q2k * get(m - l2);
        let d1 = get(m - 1) - q2k * get(m - 1 - l2);
        let vre = two_ea_cos * vre1 - e2a * vre2 + d - ea_cos * d1;
        let vim = two_ea_cos * vim1 - e2a * vim2 - ea_sin * d1;
        vre2 = vre1;
        vre1 = vre;
        vim2 = vim1;
        vim1 = vim;
        if m >= ki {
            let i = m - ki;
            let out_re = sign * scale * (vre + q2k * get(i - ki));
            let out_im = sign * scale * vim;
            c.push(out_re);
            s.push(-out_im);
        }
    }
    Components { c, s }
}

/// Untruncated attenuated filter state (eq. 34) — bounded for bounded input;
/// contrast with [`crate::sft::recursive1::filter_state`] in the precision study.
pub fn filter_state<T: Float>(x: &[T], k: usize, p: usize, alpha: f64) -> Vec<Complex<T>> {
    let beta = std::f64::consts::PI / k as f64;
    let decay = T::from_f64((-alpha).exp());
    let pole = Complex::<T>::cis(T::from_f64(-beta * p as f64)).scale(decay);
    let mut v = Complex::<T>::zero();
    x.iter()
        .map(|&xv| {
            v = pole * v + Complex::from_re(xv);
            v
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::{gaussian_noise, rel_rmse};
    use crate::sft::direct;

    #[test]
    fn r1_matches_attenuated_oracle() {
        let x: Vec<f64> = gaussian_noise(200, 1.0, 14);
        let k = 16;
        let beta = std::f64::consts::PI / 16.0;
        let alpha = 0.01;
        for p in [0, 1, 5] {
            let got = components_r1(&x, k, p, alpha);
            let want = direct::asft_components(&x, k, beta, p as f64, alpha);
            assert!(rel_rmse(&got.c, &want.c) < 1e-9, "p={p}");
            assert!(rel_rmse(&got.s, &want.s) < 1e-9, "p={p}");
        }
    }

    #[test]
    fn r2_matches_attenuated_oracle() {
        let x: Vec<f64> = gaussian_noise(160, 1.0, 15);
        let k = 12;
        let beta = std::f64::consts::PI / 12.0;
        let alpha = 0.02;
        for p in [0, 2, 7] {
            let got = components_r2(&x, k, p, alpha);
            let want = direct::asft_components(&x, k, beta, p as f64, alpha);
            assert!(rel_rmse(&got.c, &want.c) < 1e-8, "p={p}");
            assert!(rel_rmse(&got.s, &want.s) < 1e-8, "p={p}");
        }
    }

    #[test]
    fn alpha_zero_reduces_to_sft() {
        let x: Vec<f64> = gaussian_noise(120, 1.0, 16);
        let k = 10;
        let got = components_r1(&x, k, 3, 0.0);
        let want = crate::sft::recursive1::components(&x, k, 3);
        assert!(rel_rmse(&got.c, &want.c) < 1e-10);
        assert!(rel_rmse(&got.s, &want.s) < 1e-10);
    }

    #[test]
    fn state_is_bounded_where_sft_state_grows() {
        // DC input: plain SFT state at p=0 is the running sum (grows ~N);
        // ASFT state is geometric (bounded by 1/(1-e^{-α})).
        let x = vec![1.0f64; 5000];
        let alpha = 0.01;
        let asft_state = filter_state(&x, 8, 0, alpha);
        let bound = 1.0 / (1.0 - (-alpha as f64).exp()) + 1.0;
        assert!(asft_state.iter().all(|v| v.norm() < bound));
        let sft_state = crate::sft::recursive1::filter_state(&x, 8, 0);
        assert!(sft_state.last().unwrap().norm() > 4000.0);
    }

    #[test]
    fn r1_r2_agree() {
        let x: Vec<f64> = gaussian_noise(100, 1.5, 17);
        let a = components_r1(&x, 9, 4, 0.015);
        let b = components_r2(&x, 9, 4, 0.015);
        assert!(rel_rmse(&a.c, &b.c) < 1e-8);
        assert!(rel_rmse(&a.s, &b.s) < 1e-8);
    }
}
