//! Sliding Fourier transform (SFT) and attenuated SFT (ASFT) — paper §2.2-2.4.
//!
//! The p-th order components of the SFT of interval `[-K, K]` are (eqs. 7-8):
//!
//! ```text
//! c_p[n] = Σ_{k=-K}^{K} x[n-k] cos(βpk)      β = π/K
//! s_p[n] = Σ_{k=-K}^{K} x[n-k] sin(βpk)
//! ```
//!
//! with zero extension of `x` outside `[0, N)`.  Four ways to compute them,
//! each a submodule:
//!
//! * [`direct`] — the defining O(KN) sums; the oracle everything is tested
//!   against. Supports fractional orders (real frequencies ω = βp, eqs. 58-59).
//! * [`kernel_integral`] — running prefix sum of `x[j]e^{iβpj}`, window by
//!   difference (eqs. 16-20); O(N) per order, fractional orders supported.
//!   This is the formulation the GPU/Pallas kernel parallelizes.
//! * [`recursive1`] — first-order complex one-pole filter with `2K`-delay
//!   truncation (eqs. 22-28); integer orders only (needs `e^{-iβp2K} = 1`).
//! * [`recursive2`] — Sugimoto-style second-order real-coefficient filter
//!   (eqs. 30-31); numerically the most fragile, kept faithful to the paper.
//!
//! [`asft`] holds the attenuated variants (eqs. 32-39).  **Convention note**
//! (documented in the [DESIGN.md §1.1](crate::design) errata): we define the ASFT weight as `e^{-αk}`
//! relative to the window centre — the convention under which the paper's
//! *stable* filter (34) actually computes the components and under which the
//! Gaussian shift identity (eq. 40) recovers the true smoothing with
//! `x_G[n] ≈ e^{-α²/4γ} Σ_p a_p c̃_p[n-n₀]`, `n₀ = α/(2γ)`.

pub mod asft;
pub mod direct;
pub mod kernel_integral;
pub mod recursive1;
pub mod recursive2;

use crate::dsp::Float;

/// Which algorithm computes the SFT components.
#[derive(Copy, Clone, Debug, PartialEq, Eq, Default)]
pub enum Algorithm {
    /// O(KN) defining sums (oracle).
    Direct,
    /// O(N) prefix-sum kernel integral (default; fractional orders OK).
    #[default]
    KernelIntegral,
    /// O(N) first-order recursive filter (integer orders, β = π/K).
    Recursive1,
    /// O(N) second-order recursive filter (integer orders, β = π/K).
    Recursive2,
}

/// One SFT component pair `(c_p[n], s_p[n])` for the whole signal.
#[derive(Clone, Debug)]
pub struct Components<T> {
    /// Cosine components `c_p[n]`.
    pub c: Vec<T>,
    /// Sine components `s_p[n]`.
    pub s: Vec<T>,
}

/// Compute `(c_p, s_p)` for a single (possibly fractional) order.
///
/// `beta` is the base frequency (π/K for the harmonic SFT); the component
/// frequency is `beta * p`. Integer-only algorithms check that `p` is close
/// to an integer and that `beta ≈ π/K`.
pub fn components<T: Float>(
    algo: Algorithm,
    x: &[T],
    k: usize,
    beta: f64,
    p: f64,
) -> Components<T> {
    match algo {
        Algorithm::Direct => direct::components(x, k, beta, p),
        Algorithm::KernelIntegral => kernel_integral::components(x, k, beta, p),
        Algorithm::Recursive1 => {
            let pi = require_harmonic(k, beta, p);
            recursive1::components(x, k, pi)
        }
        Algorithm::Recursive2 => {
            let pi = require_harmonic(k, beta, p);
            recursive2::components(x, k, pi)
        }
    }
}

/// Compute a bank of consecutive integer orders `p = p0 .. p0+count`.
pub fn bank<T: Float>(
    algo: Algorithm,
    x: &[T],
    k: usize,
    beta: f64,
    p0: usize,
    count: usize,
) -> Vec<Components<T>> {
    (0..count)
        .map(|j| components(algo, x, k, beta, (p0 + j) as f64))
        .collect()
}

fn require_harmonic(k: usize, beta: f64, p: f64) -> usize {
    let pi_over_k = std::f64::consts::PI / k as f64;
    assert!(
        (beta - pi_over_k).abs() < 1e-9 * pi_over_k,
        "recursive filters require the harmonic SFT (beta = π/K); got beta={beta}, K={k}"
    );
    let rounded = p.round();
    assert!(
        (p - rounded).abs() < 1e-9,
        "recursive filters require integer orders; got p={p}"
    );
    rounded as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsp::{gaussian_noise, rel_rmse};

    fn check_algo_matches_direct(algo: Algorithm) {
        let x: Vec<f64> = gaussian_noise(257, 1.0, 11);
        let k = 24;
        let beta = std::f64::consts::PI / k as f64;
        for p in [0usize, 1, 3, 7] {
            let got = components(algo, &x, k, beta, p as f64);
            let want = direct::components(&x, k, beta, p as f64);
            assert!(
                rel_rmse(&got.c, &want.c) < 1e-10,
                "{algo:?} c_p mismatch at p={p}"
            );
            assert!(
                rel_rmse(&got.s, &want.s) < 1e-10,
                "{algo:?} s_p mismatch at p={p}"
            );
        }
    }

    #[test]
    fn kernel_integral_matches_direct() {
        check_algo_matches_direct(Algorithm::KernelIntegral);
    }

    #[test]
    fn recursive1_matches_direct() {
        check_algo_matches_direct(Algorithm::Recursive1);
    }

    #[test]
    fn recursive2_matches_direct() {
        check_algo_matches_direct(Algorithm::Recursive2);
    }

    #[test]
    fn bank_orders_are_consecutive() {
        let x: Vec<f64> = gaussian_noise(64, 1.0, 3);
        let k = 8;
        let beta = std::f64::consts::PI / 8.0;
        let b = bank(Algorithm::KernelIntegral, &x, k, beta, 2, 3);
        assert_eq!(b.len(), 3);
        for (j, comp) in b.iter().enumerate() {
            let want = direct::components(&x, k, beta, (2 + j) as f64);
            assert!(rel_rmse(&comp.c, &want.c) < 1e-10);
        }
    }

    #[test]
    #[should_panic(expected = "integer orders")]
    fn recursive_rejects_fractional_order() {
        let x = vec![0.0f64; 16];
        components(
            Algorithm::Recursive1,
            &x,
            4,
            std::f64::consts::PI / 4.0,
            1.5,
        );
    }

    #[test]
    #[should_panic(expected = "harmonic")]
    fn recursive_rejects_nonharmonic_beta() {
        let x = vec![0.0f64; 16];
        components(Algorithm::Recursive2, &x, 4, 0.5, 1.0);
    }
}
