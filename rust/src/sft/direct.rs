//! Direct O(KN) evaluation of the SFT defining sums (paper eqs. 7-8) —
//! the correctness oracle for every other algorithm.  Supports fractional
//! orders (real-frequency SFT, eqs. 58-59, with ω = β·p).

use super::Components;
use crate::dsp::Float;

/// `c_p[n] = Σ_{k=-K}^{K} x[n-k] cos(βpk)`, `s_p` likewise, zero extension.
pub fn components<T: Float>(x: &[T], k: usize, beta: f64, p: f64) -> Components<T> {
    let n = x.len();
    let ki = k as isize;
    // Precompute the window tables once: O(K) setup, O(KN) main loop.
    let mut cos_t = Vec::with_capacity(2 * k + 1);
    let mut sin_t = Vec::with_capacity(2 * k + 1);
    for kk in -ki..=ki {
        let th = beta * p * kk as f64;
        cos_t.push(T::from_f64(th.cos()));
        sin_t.push(T::from_f64(th.sin()));
    }
    let mut c = Vec::with_capacity(n);
    let mut s = Vec::with_capacity(n);
    for i in 0..n as isize {
        let mut ac = T::ZERO;
        let mut as_ = T::ZERO;
        // j runs over the window; x index is i - (j - K)
        let lo = (i - ki).max(0);
        let hi = (i + ki).min(n as isize - 1);
        for idx in lo..=hi {
            // idx = i - kk  =>  kk = i - idx, table slot kk + K
            let slot = (i - idx + ki) as usize;
            let xv = x[idx as usize];
            ac += xv * cos_t[slot];
            as_ += xv * sin_t[slot];
        }
        c.push(ac);
        s.push(as_);
    }
    Components { c, s }
}

/// Attenuated direct sums: weight `e^{-αk}` at window offset k (ASFT oracle).
pub fn asft_components<T: Float>(
    x: &[T],
    k: usize,
    beta: f64,
    p: f64,
    alpha: f64,
) -> Components<T> {
    let n = x.len();
    let ki = k as isize;
    let mut cos_t = Vec::with_capacity(2 * k + 1);
    let mut sin_t = Vec::with_capacity(2 * k + 1);
    for kk in -ki..=ki {
        let th = beta * p * kk as f64;
        let w = (-alpha * kk as f64).exp();
        cos_t.push(T::from_f64(w * th.cos()));
        sin_t.push(T::from_f64(w * th.sin()));
    }
    let mut c = Vec::with_capacity(n);
    let mut s = Vec::with_capacity(n);
    for i in 0..n as isize {
        let mut ac = T::ZERO;
        let mut as_ = T::ZERO;
        let lo = (i - ki).max(0);
        let hi = (i + ki).min(n as isize - 1);
        for idx in lo..=hi {
            let slot = (i - idx + ki) as usize;
            let xv = x[idx as usize];
            ac += xv * cos_t[slot];
            as_ += xv * sin_t[slot];
        }
        c.push(ac);
        s.push(as_);
    }
    Components { c, s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_zero_is_window_count_on_ones() {
        let x = vec![1.0f64; 32];
        let comp = components(&x, 4, std::f64::consts::PI / 4.0, 0.0);
        assert_eq!(comp.c[16], 9.0); // 2K+1 interior window
        assert_eq!(comp.c[0], 5.0); // half window at the edge
        assert!(comp.s.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn impulse_response_is_window_table() {
        let mut x = vec![0.0f64; 21];
        x[10] = 1.0;
        let k = 3;
        let beta = std::f64::consts::PI / 3.0;
        let comp = components(&x, k, beta, 2.0);
        for n in 0..21isize {
            let kk = n - 10; // c[n] = cos(βp(n-10)) when |n-10|<=K
            let want = if kk.abs() <= 3 {
                (beta * 2.0 * kk as f64).cos()
            } else {
                0.0
            };
            assert!((comp.c[n as usize] - want).abs() < 1e-12);
        }
    }

    #[test]
    fn fractional_order_frequency() {
        let mut x = vec![0.0f64; 11];
        x[5] = 1.0;
        let comp = components(&x, 2, 0.7, 1.5);
        // c[6]: offset kk = 1 -> cos(0.7*1.5*1)
        assert!((comp.c[6] - (1.05f64).cos()).abs() < 1e-12);
        assert!((comp.s[6] - (1.05f64).sin()).abs() < 1e-12);
    }

    #[test]
    fn asft_alpha_zero_equals_sft() {
        let x: Vec<f64> = (0..40).map(|i| (i as f64 * 0.3).sin()).collect();
        let a = components(&x, 5, std::f64::consts::PI / 5.0, 2.0);
        let b = asft_components(&x, 5, std::f64::consts::PI / 5.0, 2.0, 0.0);
        assert_eq!(a.c, b.c);
        assert_eq!(a.s, b.s);
    }

    #[test]
    fn asft_weights_decay_with_offset() {
        // impulse at n-k: weight on c at output n is e^{-αk}cos(βpk)
        let mut x = vec![0.0f64; 21];
        x[10] = 1.0;
        let alpha = 0.1;
        let comp = asft_components(&x, 4, std::f64::consts::PI / 4.0, 0.0, alpha);
        // output index n = 10 + kk reads the impulse at offset kk ... careful:
        // c[n] = Σ_k x[n-k] w[k] -> x[10]=1 contributes at n = 10 + k with w[k]
        for kk in -4isize..=4 {
            let nidx = (10 + kk) as usize;
            let want = (-alpha * kk as f64).exp();
            assert!((comp.c[nidx] - want).abs() < 1e-12, "kk={kk}");
        }
    }
}
