//! `masft` CLI — leader entrypoint for the reproduction.
//!
//! ```text
//! masft selftest                        quick numeric check of every path
//! masft transform  [--n N --sigma S --xi X --method M]
//! masft scalogram  [--n N --scales K]
//! masft figures    [--outdir D] [--only table1,fig5,...] [--quick] [--cpu]
//! masft precision  [--k K --p P]
//! masft serve      [--requests R --clients C --workers W --pjrt] in-process load test
//!                  [--streams S --stream-blocks B --block-len N] streaming-session phase
//!                  [--listen ADDR] network mode: serve the DESIGN.md §10 wire protocol
//!                  on a TCP address or `unix:<path>`; with --requests/--streams it
//!                  drives a loopback smoke load through the socket and exits (CI mode),
//!                  otherwise it serves until stdin reaches EOF
//!                  [--io threads|poll] connection multiplexing model: one thread
//!                  per connection (default) or the DESIGN.md §10.5 readiness loop
//!                  [--profile PATH] install a tuning profile for Auto resolution
//! masft connect    --addr ADDR [--n N --sigma S --p P] one-shot client for a
//!                  running `serve --listen`
//! masft calibrate  [--quick] [--out PATH] micro-benchmark the backend/precision
//!                  crossovers on this host and write (merge) a tuning profile
//!                  (DESIGN.md §11); serve/library pick it up via --profile /
//!                  Config::tuning_profile
//! ```

// Wall-clock reads are this layer's job (CLI progress timing) — the workspace-wide
// clippy `disallowed-methods` ban (clippy.toml, masft-lint:
// no-wall-clock-in-core) exists to keep them OUT of the numeric core,
// not out of here.
#![allow(clippy::disallowed_methods)]
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Duration;

use masft::bench_harness as bh;
use masft::coordinator::{BatchPolicy, Config, Coordinator, Request, Transform};
use masft::dsp::SignalBuilder;
use masft::gaussian::GaussianSmoother;
use masft::morlet::{scalogram, Method, MorletTransform};
use masft::plan::{MorletSpec, TransformSpec};
use masft::precision;
use masft::runtime::PjrtExecutor;
use masft::server::{Client, ClientOptions, IoModel, Server, ServerConfig};
use masft::streaming::BlockOut;
use masft::Result;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, opts) = parse(&args);
    let outcome = match cmd.as_deref() {
        Some("selftest") => selftest(&opts),
        Some("transform") => transform_cmd(&opts),
        Some("scalogram") => scalogram_cmd(&opts),
        Some("figures") => figures(&opts),
        Some("precision") => precision_cmd(&opts),
        Some("serve") => serve(&opts),
        Some("connect") => connect_cmd(&opts),
        Some("calibrate") => calibrate_cmd(&opts),
        _ => {
            eprintln!(
                "usage: masft <selftest|transform|scalogram|figures|precision|serve|connect|calibrate> [--key value|--flag]"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = outcome {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `--key value` pairs and bare `--flag`s.
fn parse(args: &[String]) -> (Option<String>, HashMap<String, String>) {
    let mut cmd = None;
    let mut opts = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                opts.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                opts.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            if cmd.is_none() {
                cmd = Some(a.clone());
            }
            i += 1;
        }
    }
    (cmd, opts)
}

fn get<T: std::str::FromStr>(opts: &HashMap<String, String>, key: &str, default: T) -> T {
    opts.get(key)
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn flag(opts: &HashMap<String, String>, key: &str) -> bool {
    opts.get(key).map(|v| v == "true").unwrap_or(false)
}

fn selftest(opts: &HashMap<String, String>) -> Result<()> {
    println!("== masft selftest ==");
    let x = SignalBuilder::new(2048)
        .sine(0.004, 1.0, 0.1)
        .chirp(0.001, 0.04, 0.6)
        .noise(0.3)
        .build();

    let sm = GaussianSmoother::new(20.0, 6)?;
    let e_g = masft::gaussian::interior_rel_rmse(&sm.smooth_sft(&x), &sm.smooth_direct(&x), sm.k);
    println!("gaussian GDP6 vs GCT3 rel-RMSE: {e_g:.2e}");
    anyhow::ensure!(e_g < 0.01, "gaussian check failed");

    let base = MorletTransform::new(20.0, 6.0, Method::TruncatedConv)?;
    let want = base.transform(&x);
    for (name, method) in [
        ("MDP6", Method::DirectSft { p_d: 6 }),
        ("MDS10P6", Method::DirectAsft { p_d: 6, n0: 10 }),
        ("MMP3", Method::MultiplySft { p_m: 3 }),
    ] {
        let mt = MorletTransform::new(20.0, 6.0, method)?;
        let got = mt.transform(&x);
        let e = masft::dsp::rel_rmse_complex(&got[200..1848], &want[200..1848]);
        println!("morlet {name} vs MCT3 rel-RMSE: {e:.2e}");
        anyhow::ensure!(e < 0.05, "morlet {name} check failed");
    }

    // coordinator (pure backend)
    let coord = Coordinator::start_pure(Config::default());
    let resp = coord.handle().transform(Request {
        signal: x.iter().map(|&v| v as f32).collect(),
        transform: Transform::MorletDirect {
            sigma: 20.0,
            xi: 6.0,
            p_d: 6,
        },
    })?;
    println!(
        "coordinator (pure): served {} samples in {}",
        resp.re.len(),
        masft::util::fmt_ns(resp.meta.exec_ns as f64)
    );
    coord.shutdown();

    // PJRT path, if artifacts exist
    let dir = artifacts_dir(opts);
    if dir.join("manifest.json").exists() {
        let coord = Coordinator::start(Config::default(), move || {
            Ok(Box::new(PjrtExecutor::load(&dir)?))
        });
        let resp = coord.handle().transform(Request {
            signal: x.iter().take(1024).map(|&v| v as f32).collect(),
            transform: Transform::Gaussian { sigma: 12.0, p: 6 },
        })?;
        println!(
            "coordinator (pjrt): served {} samples in {} [{}]",
            resp.re.len(),
            masft::util::fmt_ns(resp.meta.exec_ns as f64),
            coord.stats().backend,
        );
        coord.shutdown();
    } else {
        println!("(artifacts missing — PJRT path skipped; run `make artifacts`)");
    }
    println!("selftest OK");
    Ok(())
}

fn artifacts_dir(opts: &HashMap<String, String>) -> PathBuf {
    opts.get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(PjrtExecutor::default_dir)
}

fn transform_cmd(opts: &HashMap<String, String>) -> Result<()> {
    let n: usize = get(opts, "n", 4096);
    let sigma: f64 = get(opts, "sigma", 30.0);
    let xi: f64 = get(opts, "xi", 6.0);
    let method = match opts.get("method").map(String::as_str).unwrap_or("mdp6") {
        "mct3" => Method::TruncatedConv,
        "mdp6" => Method::DirectSft { p_d: 6 },
        "mds" => Method::DirectAsft { p_d: 6, n0: 10 },
        "mmp3" => Method::MultiplySft { p_m: 3 },
        other => anyhow::bail!("unknown method {other} (mct3|mdp6|mds|mmp3)"),
    };
    let x = SignalBuilder::new(n)
        .chirp(0.001, 0.05, 1.0)
        .noise(0.2)
        .build();
    let mt = MorletTransform::new(sigma, xi, method)?;
    let t0 = std::time::Instant::now();
    let z = mt.transform(&x);
    let dt = t0.elapsed();
    let energy: f64 = z.iter().map(|c| c.norm_sq()).sum();
    println!(
        "method={:?} N={n} sigma={sigma} xi={xi} K={} P_S={:?}",
        mt.method, mt.k, mt.p_s()
    );
    println!("time: {dt:?}   output energy: {energy:.4}");
    Ok(())
}

fn scalogram_cmd(opts: &HashMap<String, String>) -> Result<()> {
    let n: usize = get(opts, "n", 6000);
    let scales: usize = get(opts, "scales", 16);
    let x = SignalBuilder::new(n).chirp(0.001, 0.06, 1.0).noise(0.1).build();
    let sigmas: Vec<f64> = (0..scales)
        .map(|i| 10.0 * (1.25f64).powi(i as i32))
        .collect();
    let sg = scalogram(&x, 6.0, &sigmas, Method::DirectSft { p_d: 6 })?;
    print_ascii_scalogram(&sg, 100);
    Ok(())
}

fn print_ascii_scalogram(sg: &masft::morlet::Scalogram, cols: usize) {
    let ramp: &[u8] = b" .:-=+*#%@";
    let n = sg.rows[0].len();
    let step = (n / cols).max(1);
    let maxv = sg
        .rows
        .iter()
        .flat_map(|r| r.iter())
        .cloned()
        .fold(f64::MIN, f64::max)
        .max(1e-12);
    println!("scalogram ({} scales x {} samples, downsampled):", sg.rows.len(), n);
    for (s, row) in sg.rows.iter().enumerate().rev() {
        let mut line = String::new();
        for c in 0..cols.min(n / step) {
            let w = &row[c * step..((c + 1) * step).min(n)];
            let v = w.iter().cloned().fold(0.0f64, f64::max) / maxv;
            let idx = ((v * (ramp.len() - 1) as f64).round() as usize).min(ramp.len() - 1);
            line.push(ramp[idx] as char);
        }
        println!("σ={:7.1} f={:.4} |{}|", sg.sigmas[s], sg.centre_freq(s), line);
    }
}

fn figures(opts: &HashMap<String, String>) -> Result<()> {
    let outdir = PathBuf::from(
        opts.get("outdir")
            .cloned()
            .unwrap_or_else(|| "results".to_string()),
    );
    std::fs::create_dir_all(&outdir)?;
    let only: Option<Vec<String>> = opts
        .get("only")
        .map(|s| s.split(',').map(|v| v.trim().to_string()).collect());
    let want = |name: &str| only.as_ref().map(|o| o.iter().any(|v| v == name)).unwrap_or(true);
    let quick = flag(opts, "quick");
    let cpu = flag(opts, "cpu");

    if want("table1") {
        println!("\n=== Table 1: relative RMSE (%) of Gaussian fits (K=256, n0=10, beta tuned) ===");
        let rows = if quick {
            masft::bench_harness::table1_rows_with_k(128, 5)
        } else {
            bh::table1_rows()
        };
        let cells: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    r.transform.to_string(),
                    r.p.to_string(),
                    format!("{:.4}", r.e_g_pct),
                    format!("{:.3}", r.e_gd_pct),
                    format!("{:.3}", r.e_gdd_pct),
                ]
            })
            .collect();
        let headers = ["Transform", "P", "e(G) %", "e(G_D) %", "e(G_DD) %"];
        println!("{}", bh::render_table(&headers, &cells));
        std::fs::write(outdir.join("table1.csv"), bh::render_csv(&headers, &cells))?;
    }

    let xis: Vec<f64> = if quick {
        vec![2.0, 6.0, 12.0, 18.0]
    } else {
        (1..=20).map(|i| i as f64).collect()
    };

    if want("fig5") {
        println!("\n=== Fig 5: Morlet fit relative RMSE vs xi (sigma=60, K tuned) ===");
        let rows = bh::fig5_rows(&xis);
        let headers = ["variant", "xi", "rmse", "K"];
        let cells: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    r.variant.clone(),
                    format!("{:.1}", r.xi),
                    format!("{:.4e}", r.rmse),
                    r.k.to_string(),
                ]
            })
            .collect();
        println!("{}", bh::render_table(&headers, &cells));
        std::fs::write(outdir.join("fig5.csv"), bh::render_csv(&headers, &cells))?;
    }

    if want("fig6") {
        println!("\n=== Fig 6: MDP6 / MDS5P6 vs truncated [-3sigma,3sigma] ===");
        let rows = bh::fig6_rows(&xis);
        let headers = ["variant", "xi", "rmse", "K"];
        let cells: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    r.variant.clone(),
                    format!("{:.1}", r.xi),
                    format!("{:.4e}", r.rmse),
                    r.k.to_string(),
                ]
            })
            .collect();
        println!("{}", bh::render_table(&headers, &cells));
        std::fs::write(outdir.join("fig6.csv"), bh::render_csv(&headers, &cells))?;
    }

    if want("fig7") {
        println!("\n=== Fig 7: optimal P_S vs xi (sigma=60, P_D=6) ===");
        let rows = bh::fig7_rows(&xis);
        let headers = ["xi", "P_S", "rmse"];
        let cells: Vec<Vec<String>> = rows
            .iter()
            .map(|r| {
                vec![
                    format!("{:.1}", r.xi),
                    r.p_s.to_string(),
                    format!("{:.4e}", r.rmse),
                ]
            })
            .collect();
        println!("{}", bh::render_table(&headers, &cells));
        std::fs::write(outdir.join("fig7.csv"), bh::render_csv(&headers, &cells))?;
    }

    for (name, gauss) in [("fig8", true), ("fig9", false)] {
        if !want(name) {
            continue;
        }
        let label = if gauss { "Gaussian smoothing" } else { "Morlet transform" };
        for (sweep_n, suffix) in [(true, "n_sweep"), (false, "sigma_sweep")] {
            println!("\n=== {name} ({label}, GPU cost model, {suffix}) ===");
            let rows = if gauss {
                bh::fig8_model_rows(sweep_n)
            } else {
                bh::fig9_model_rows(sweep_n)
            };
            print_and_save_timing(&outdir, &format!("{name}_model_{suffix}"), &rows)?;
            if cpu {
                println!("=== {name} ({label}, real CPU wall-clock, {suffix}) ===");
                let rows = if gauss {
                    bh::fig8_cpu_rows(sweep_n, quick)
                } else {
                    bh::fig9_cpu_rows(sweep_n, quick)
                };
                print_and_save_timing(&outdir, &format!("{name}_cpu_{suffix}"), &rows)?;
            }
        }
    }
    println!("\nCSV written to {}", outdir.display());
    Ok(())
}

fn print_and_save_timing(outdir: &Path, name: &str, rows: &[bh::TimingRow]) -> Result<()> {
    let headers = ["x", "conv_ms", "proposed_ms", "speedup"];
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                format!("{:.0}", r.x),
                format!("{:.4}", r.conv_ms),
                format!("{:.4}", r.proposed_ms),
                format!("{:.1}", r.speedup()),
            ]
        })
        .collect();
    println!("{}", bh::render_table(&headers, &cells));
    std::fs::write(
        outdir.join(format!("{name}.csv")),
        bh::render_csv(&headers, &cells),
    )?;
    Ok(())
}

fn precision_cmd(opts: &HashMap<String, String>) -> Result<()> {
    let k: usize = get(opts, "k", 64);
    let p: usize = get(opts, "p", 2);
    let alpha: f64 = get(opts, "alpha", 0.005);
    println!("=== f32 drift: relative RMSE vs f64 oracle (K={k}, p={p}, alpha={alpha}) ===");
    let lengths = [1_000usize, 5_000, 20_000, 50_000, 100_000];
    let rows = precision::drift_experiment(&lengths, k, p, alpha);
    let headers = [
        "N",
        "recursive1",
        "recursive2",
        "ASFT",
        "prefix",
        "gpu_window",
        "tier_kernel",
    ];
    let cells: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                format!("{:.2e}", r.recursive1_f32),
                format!("{:.2e}", r.recursive2_f32),
                format!("{:.2e}", r.asft_f32),
                format!("{:.2e}", r.prefix_f32),
                format!("{:.2e}", r.gpu_window_f32),
                format!("{:.2e}", r.kernel_f32),
            ]
        })
        .collect();
    println!("{}", bh::render_table(&headers, &cells));
    println!("=== filter state growth (max |v[n]|) ===");
    for (n, sft, asft) in precision::state_growth(&[1_000, 10_000, 100_000], k, alpha) {
        println!("N={n:>7}: SFT state {sft:>12.1}  ASFT state {asft:>8.1}");
    }
    Ok(())
}

fn serve(opts: &HashMap<String, String>) -> Result<()> {
    if let Some(listen) = opts.get("listen") {
        return serve_listen(listen, opts);
    }
    let requests: usize = get(opts, "requests", 200);
    let clients: usize = get(opts, "clients", 4);
    let workers: usize = get(opts, "workers", 1);
    let use_pjrt = flag(opts, "pjrt");
    let dir = artifacts_dir(opts);
    let coord = if use_pjrt {
        anyhow::ensure!(
            dir.join("manifest.json").exists(),
            "artifacts missing at {} — run `make artifacts`",
            dir.display()
        );
        Coordinator::start(
            Config {
                policy: BatchPolicy {
                    max_batch: 16,
                    max_delay: Duration::from_millis(2),
                },
                queue_cap: 512,
                workers,
                tuning_profile: opts.get("profile").map(PathBuf::from),
                ..Config::default()
            },
            move || Ok(Box::new(PjrtExecutor::load(&dir)?)),
        )
    } else {
        Coordinator::start_pure(Config {
            workers,
            tuning_profile: opts.get("profile").map(PathBuf::from),
            ..Config::default()
        })
    };

    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let h = coord.handle();
        let per = requests / clients;
        joins.push(std::thread::spawn(move || {
            for i in 0..per {
                let n = [512usize, 900, 1024][(c + i) % 3];
                let x = SignalBuilder::new(n)
                    .seed((c * 1000 + i) as u64)
                    .sine(0.01, 1.0, 0.0)
                    .noise(0.3)
                    .build_f32();
                let transform = if i % 3 == 0 {
                    Transform::Gaussian { sigma: 12.0, p: 6 }
                } else {
                    Transform::MorletDirect {
                        sigma: 15.0,
                        xi: 6.0,
                        p_d: 6,
                    }
                };
                h.transform(Request { signal: x, transform }).expect("served");
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }
    let dt = t0.elapsed();

    // Streaming-session phase: S concurrent clients, each pushing chirp
    // blocks through one long-lived bounded-state session, twice over with
    // a reset() in between (the session-reuse lifecycle).
    let streams: usize = get(opts, "streams", 0);
    let stream_blocks: usize = get(opts, "stream-blocks", 16);
    let block_len: usize = get(opts, "block-len", 2048);
    if streams > 0 {
        let t1 = std::time::Instant::now();
        let mut joins = Vec::new();
        for c in 0..streams {
            let h = coord.handle();
            joins.push(std::thread::spawn(move || {
                let spec: TransformSpec =
                    MorletSpec::builder(12.0, 6.0).build().unwrap().into();
                let mut session = h.open_stream(&spec).expect("stream session");
                let mut served = 0usize;
                for round in 0..2usize {
                    for b in 0..stream_blocks {
                        let x = SignalBuilder::new(block_len)
                            .seed((c * 7919 + round * 131 + b) as u64)
                            .chirp(0.001, 0.05, 1.0)
                            .noise(0.2)
                            .build();
                        served += session.push_block(&x).re.len();
                    }
                    served += session.finish().re.len();
                    session.reset();
                }
                assert_eq!(
                    served,
                    2 * stream_blocks * block_len,
                    "every ingested sample must emerge exactly once"
                );
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        let ds = t1.elapsed();
        let samples = 2 * streams * stream_blocks * block_len;
        println!(
            "streamed {samples} samples across {streams} sessions in {ds:?} -> {:.1} Msamp/s",
            samples as f64 / ds.as_secs_f64() / 1e6
        );
    }

    let stats = coord.stats();
    let served = stats.e2e.count;
    println!("{}", stats.report());
    println!(
        "served {served} requests in {dt:?} -> {:.0} req/s",
        served as f64 / dt.as_secs_f64()
    );
    coord.shutdown();
    Ok(())
}

/// `serve --listen <addr>`: put the coordinator behind the DESIGN.md §10
/// wire protocol on a TCP address (`host:port`, port 0 picks a free one) or
/// a Unix-domain socket (`unix:<path>`).
///
/// With `--requests R` and/or `--streams S` the process drives its own
/// loopback smoke load through [`Client`] — real sockets, real frames — and
/// exits; this is the CI smoke mode. Without either, it serves until stdin
/// reaches EOF (so `masft serve --listen addr < /dev/null` exits cleanly
/// and an interactive run stops on Ctrl-D).
fn serve_listen(listen: &str, opts: &HashMap<String, String>) -> Result<()> {
    let workers: usize = get(opts, "workers", 1);
    let io = match opts.get("io").map(String::as_str) {
        None => IoModel::Threads,
        Some(v) => IoModel::parse(v)
            .ok_or_else(|| anyhow::anyhow!("--io must be `threads` or `poll`, got `{v}`"))?,
    };
    let coord = Coordinator::start_pure(Config {
        workers,
        tuning_profile: opts.get("profile").map(PathBuf::from),
        ..Config::default()
    });
    let server = Server::bind(
        listen,
        coord.handle(),
        ServerConfig {
            io,
            ..ServerConfig::default()
        },
    )?;
    let addr = server.local_addr();
    println!("serving the masft wire protocol on {addr} (io model: {io})");

    let requests: usize = get(opts, "requests", 0);
    let streams: usize = get(opts, "streams", 0);
    if requests == 0 && streams == 0 {
        println!("(close stdin to stop)");
        let mut sink = String::new();
        let _ = std::io::Read::read_to_string(&mut std::io::stdin(), &mut sink);
        server.shutdown();
        coord.shutdown();
        return Ok(());
    }

    // Batch smoke: C loopback connections, each a real socket client.
    let clients: usize = get(opts, "clients", 2).max(1);
    let t0 = std::time::Instant::now();
    let mut joins = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let per = requests / clients + usize::from(c < requests % clients);
        joins.push(std::thread::spawn(move || -> Result<usize> {
            // alternate codec-advertising clients so the smoke exercises
            // both the compressed and raw reply paths end to end
            let mut client = if c % 2 == 0 {
                Client::connect_with(&addr, ClientOptions { codec: true })?
            } else {
                Client::connect(&addr)?
            };
            for i in 0..per {
                let n = [512usize, 900, 1024][(c + i) % 3];
                let x = SignalBuilder::new(n)
                    .seed((c * 1000 + i) as u64)
                    .sine(0.01, 1.0, 0.0)
                    .noise(0.3)
                    .build_f32();
                let transform = if i % 2 == 0 {
                    Transform::Gaussian { sigma: 12.0, p: 6 }
                } else {
                    Transform::MorletDirect {
                        sigma: 15.0,
                        xi: 6.0,
                        p_d: 6,
                    }
                };
                let resp = client.transform(&transform, &x)?;
                anyhow::ensure!(resp.re.len() == n, "short reply: {}", resp.re.len());
            }
            Ok(per)
        }));
    }
    let mut served = 0usize;
    for j in joins {
        served += j.join().expect("smoke client thread")?;
    }
    let dt = t0.elapsed();

    // Stream smoke: S sessions, one loopback connection each, sample
    // conservation asserted end to end.
    let mut streamed = 0usize;
    if streams > 0 {
        let stream_blocks: usize = get(opts, "stream-blocks", 8);
        let block_len: usize = get(opts, "block-len", 1024);
        let mut joins = Vec::new();
        for s in 0..streams {
            let addr = addr.clone();
            joins.push(std::thread::spawn(move || -> Result<usize> {
                let mut client = Client::connect(&addr)?;
                let spec: TransformSpec = MorletSpec::builder(12.0, 6.0).build()?.into();
                let (sid, _latency) = client.open_stream(&spec)?;
                let mut out = BlockOut::default();
                let mut n = 0usize;
                for b in 0..stream_blocks {
                    let x = SignalBuilder::new(block_len)
                        .seed((s * 7919 + b) as u64)
                        .chirp(0.001, 0.05, 1.0)
                        .noise(0.2)
                        .build();
                    client.push_block(sid, &x, &mut out)?;
                    n += out.re.len();
                }
                client.finish(sid, &mut out)?;
                n += out.re.len();
                client.close_stream(sid)?;
                anyhow::ensure!(
                    n == stream_blocks * block_len,
                    "every ingested sample must emerge exactly once ({n})"
                );
                Ok(n)
            }));
        }
        for j in joins {
            streamed += j.join().expect("smoke stream thread")?;
        }
    }

    println!("{}", coord.stats().report());
    println!(
        "loopback smoke: {served} batch requests in {dt:?}; {streamed} stream samples over {streams} sessions"
    );
    server.shutdown();
    coord.shutdown();
    println!("serve smoke OK");
    Ok(())
}

/// `connect --addr <addr>`: handshake with a running `serve --listen`,
/// ping, submit one Gaussian batch over the wire, and report the reply.
fn connect_cmd(opts: &HashMap<String, String>) -> Result<()> {
    let addr = opts
        .get("addr")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("connect requires --addr <host:port|unix:path>"))?;
    let n: usize = get(opts, "n", 4096);
    let sigma: f64 = get(opts, "sigma", 12.0);
    let p: usize = get(opts, "p", 6);
    let mut client = Client::connect(&addr)?;
    client.ping()?;
    let x = SignalBuilder::new(n)
        .seed(1)
        .sine(0.01, 1.0, 0.0)
        .noise(0.3)
        .build_f32();
    let t0 = std::time::Instant::now();
    let resp = client.transform(&Transform::Gaussian { sigma, p }, &x)?;
    let rtt = t0.elapsed();
    println!(
        "{addr}: served {} samples, round-trip {rtt:?} (server exec {})",
        resp.re.len(),
        masft::util::fmt_ns(resp.meta.exec_ns as f64)
    );
    Ok(())
}

/// `calibrate [--quick] [--out PATH]`: measure the backend/precision
/// crossovers on this host with the wall-clock measurer and write (merging
/// with any decisions already on disk) the tuning profile that
/// `Backend::Auto`/`Precision::Auto` resolution consults (DESIGN.md §11).
fn calibrate_cmd(opts: &HashMap<String, String>) -> Result<()> {
    let quick = flag(opts, "quick");
    let out = PathBuf::from(
        opts.get("out")
            .cloned()
            .unwrap_or_else(|| "masft-tune.profile".to_string()),
    );
    let cal_opts = masft::tune::CalibrateOptions { quick };
    let mut measurer = if quick {
        masft::tune::WallClock::quick()
    } else {
        masft::tune::WallClock::default()
    };
    println!(
        "== masft calibrate ({}) ==",
        if quick { "quick grid" } else { "full grid" }
    );
    let t0 = std::time::Instant::now();
    let profile = masft::tune::run_calibration(&mut measurer, &cal_opts)?;
    let dt = t0.elapsed();
    for d in profile.decisions() {
        println!("  {}", d.render());
    }
    profile.store(&out)?;
    println!(
        "calibrated {} decisions in {dt:?} -> {}",
        profile.len(),
        out.display()
    );
    Ok(())
}
